//! Replica membership: the static `--fleet-replicas` list plus live
//! health state and routing counters.
//!
//! Health is pessimistic-fast, optimistic-slow: the router marks a
//! replica down the moment a forward fails (the request at hand fails
//! over immediately; no client-visible error), and a background prober
//! brings it back only after it answers a `Stats` round-trip. Probe
//! failures back off exponentially per replica so a long-dead peer costs
//! one cheap connect attempt every few seconds, not every interval.

use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::wire::WireClient;
use crate::{log_info, log_warn};

/// A replica's routing availability.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// Routable (initial state; restored by a successful probe).
    Healthy,
    /// A forward or probe failed; requests fail over until a probe
    /// succeeds.
    Down,
}

/// One downstream coordinator replica: address, health, and the
/// router-side counters `fleet_stats` reports.
pub struct Replica {
    pub addr: String,
    state: AtomicU8,
    /// Requests forwarded here (first attempts on the replica's own
    /// ring slice).
    pub routed: AtomicU64,
    /// Additional attempts made here after another replica failed
    /// mid-request.
    pub retried: AtomicU64,
    /// Requests this replica absorbed for a down peer's ring slice.
    pub failed_over: AtomicU64,
    /// Forwards currently awaiting a downstream reply (bounded-load
    /// balancing input).
    pub in_flight: AtomicU64,
    /// Probe backoff, milliseconds (doubles per failure, reset on
    /// success).
    backoff_ms: AtomicU64,
    /// Milliseconds of backoff still to elapse before the next probe.
    probe_wait_ms: AtomicU64,
    /// Monotonic health-transition counter: bumped every time this
    /// replica flips healthy→down or down→healthy. The router stamps
    /// each pooled downstream connection with the epoch it was dialed
    /// under; a mismatch means the peer bounced since then, so the stale
    /// socket (pointing at the dead incarnation) is evicted and re-dialed
    /// instead of burning a failover on its inevitable write error.
    epoch: AtomicU64,
}

impl Replica {
    fn new(addr: String) -> Replica {
        Replica {
            addr,
            state: AtomicU8::new(ReplicaHealth::Healthy as u8),
            routed: AtomicU64::new(0),
            retried: AtomicU64::new(0),
            failed_over: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            backoff_ms: AtomicU64::new(0),
            probe_wait_ms: AtomicU64::new(0),
            epoch: AtomicU64::new(0),
        }
    }

    /// Current health-transition epoch (see the field doc).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    pub fn health(&self) -> ReplicaHealth {
        if self.state.load(Ordering::Relaxed) == ReplicaHealth::Healthy as u8 {
            ReplicaHealth::Healthy
        } else {
            ReplicaHealth::Down
        }
    }

    pub fn is_alive(&self) -> bool {
        self.health() == ReplicaHealth::Healthy
    }
}

/// The fleet's replica set. The list is static (`--fleet-replicas`);
/// only health and counters change at runtime.
pub struct Membership {
    pub replicas: Vec<Arc<Replica>>,
}

impl Membership {
    pub fn new(addrs: &[String]) -> Result<Arc<Membership>> {
        if addrs.is_empty() {
            return Err(anyhow!("--fleet-replicas must name at least one replica"));
        }
        Ok(Arc::new(Membership {
            replicas: addrs.iter().cloned().map(|a| Arc::new(Replica::new(a))).collect(),
        }))
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn alive_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.is_alive()).count()
    }

    /// Total forwards currently in flight across the fleet (bounded-load
    /// denominator).
    pub fn total_in_flight(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.in_flight.load(Ordering::Relaxed))
            .sum()
    }

    /// A forward to `i` failed: stop routing there until a probe
    /// succeeds.
    pub fn mark_down(&self, i: usize) {
        let r = &self.replicas[i];
        let was = r
            .state
            .swap(ReplicaHealth::Down as u8, Ordering::Relaxed);
        if was == ReplicaHealth::Healthy as u8 {
            r.epoch.fetch_add(1, Ordering::Relaxed);
            log_warn!("fleet replica {} marked down", r.addr);
        }
    }

    pub fn mark_healthy(&self, i: usize) {
        let r = &self.replicas[i];
        let was = r
            .state
            .swap(ReplicaHealth::Healthy as u8, Ordering::Relaxed);
        r.backoff_ms.store(0, Ordering::Relaxed);
        if was == ReplicaHealth::Down as u8 {
            r.epoch.fetch_add(1, Ordering::Relaxed);
            log_info!("fleet replica {} healthy again", r.addr);
        }
    }

    /// Spawn the background health prober: every `interval` it pings
    /// every replica whose backoff has elapsed with a `Stats` round-trip,
    /// restoring down replicas that answer and downing healthy ones that
    /// stopped answering. Runs for the router's lifetime.
    pub fn spawn_prober(self: &Arc<Self>, interval: Duration) {
        let me = self.clone();
        std::thread::Builder::new()
            .name("dippm-fleet-prober".into())
            .spawn(move || loop {
                std::thread::sleep(interval);
                let step_ms = interval.as_millis().max(1) as u64;
                prober_tick(&me, step_ms, &mut |_, addr| probe(addr, interval).is_ok());
            })
            .expect("spawn fleet prober");
    }
}

/// One prober pass over the replica set: probe every replica whose
/// backoff has elapsed, restore/down each from the result, and advance
/// the per-replica exponential schedule (1x → 2x → 4x … 32x the tick
/// interval between probes of a dead peer; a successful probe resets it).
/// `step_ms` is the tick cadence in milliseconds and `probe` answers
/// whether a replica responded — no clock, no sockets, so tests drive
/// ticks with a fake probe instead of sleeping.
pub(crate) fn prober_tick(
    me: &Membership,
    step_ms: u64,
    probe: &mut dyn FnMut(usize, &str) -> bool,
) {
    for (i, r) in me.replicas.iter().enumerate() {
        // Down replicas probe on an exponential schedule: skip this
        // tick while backoff is still elapsing.
        let wait = r.probe_wait_ms.load(Ordering::Relaxed);
        if wait > step_ms {
            r.probe_wait_ms.store(wait - step_ms, Ordering::Relaxed);
            continue;
        }
        if probe(i, &r.addr) {
            me.mark_healthy(i);
            r.probe_wait_ms.store(0, Ordering::Relaxed);
        } else {
            me.mark_down(i);
            // 1x → 2x → 4x … 32x the interval between probes.
            let next = (r.backoff_ms.load(Ordering::Relaxed) * 2)
                .clamp(step_ms, step_ms * 32);
            r.backoff_ms.store(next, Ordering::Relaxed);
            r.probe_wait_ms.store(next, Ordering::Relaxed);
        }
    }
}

/// One health probe: bounded connect + a `Stats` round-trip (proves the
/// replica's reactor is serving, not merely accepting).
fn probe(addr: &str, timeout: Duration) -> Result<()> {
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow!("{addr} resolves to no address"))?;
    let timeout = timeout.max(Duration::from_millis(100));
    let stream = TcpStream::connect_timeout(&sock, timeout)?;
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    let mut client = WireClient::from_stream(stream);
    client.stats().map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_transitions_and_counts() {
        let m = Membership::new(&["a:1".into(), "b:2".into(), "c:3".into()]).unwrap();
        assert_eq!(m.alive_count(), 3);
        m.mark_down(1);
        assert_eq!(m.alive_count(), 2);
        assert_eq!(m.replicas[1].health(), ReplicaHealth::Down);
        assert!(m.replicas[0].is_alive());
        m.mark_healthy(1);
        assert_eq!(m.alive_count(), 3);
    }

    #[test]
    fn empty_replica_list_rejected() {
        assert!(Membership::new(&[]).is_err());
    }

    #[test]
    fn probe_fails_fast_on_dead_port() {
        // Reserved port 1 on localhost: nothing listens there.
        assert!(probe("127.0.0.1:1", Duration::from_millis(200)).is_err());
    }

    /// Drive `ticks` fake-clock prober ticks against one always-failing
    /// replica, returning the tick numbers (1-based) at which a probe
    /// actually fired.
    fn failing_probe_ticks(m: &Membership, ticks: u64, step_ms: u64) -> Vec<u64> {
        let mut fired = Vec::new();
        for tick in 1..=ticks {
            prober_tick(m, step_ms, &mut |_, _| {
                fired.push(tick);
                false
            });
        }
        fired
    }

    #[test]
    fn prober_backoff_doubles_to_32x_then_holds() {
        let m = Membership::new(&["a:1".into()]).unwrap();
        let fired = failing_probe_ticks(&m, 200, 100);
        // First probe fires on the first tick (no backoff yet); every
        // failure then doubles the gap until it pins at 32 ticks.
        let gaps: Vec<u64> = fired.windows(2).map(|w| w[1] - w[0]).collect();
        assert_eq!(fired[0], 1, "first probe must not wait");
        assert_eq!(&gaps[..6], &[1, 2, 4, 8, 16, 32], "schedule: {gaps:?}");
        assert!(
            gaps[6..].iter().all(|&g| g == 32),
            "backoff must hold at 32x: {gaps:?}"
        );
        assert_eq!(m.replicas[0].health(), ReplicaHealth::Down);
    }

    #[test]
    fn successful_probe_resets_backoff_and_restores_health() {
        let m = Membership::new(&["a:1".into()]).unwrap();
        // Fail long enough to reach the 32x cap…
        failing_probe_ticks(&m, 70, 100);
        assert_eq!(m.replicas[0].health(), ReplicaHealth::Down);
        // …wait out the pending backoff, then answer one probe.
        let mut answered = false;
        for _ in 0..33 {
            prober_tick(&m, 100, &mut |_, _| {
                answered = true;
                true
            });
            if answered {
                break;
            }
        }
        assert!(answered, "probe never fired after the capped backoff");
        assert_eq!(m.replicas[0].health(), ReplicaHealth::Healthy);
        // The reset must restart the schedule at 1x, not resume at 32x.
        let fired = failing_probe_ticks(&m, 8, 100);
        let gaps: Vec<u64> = fired.windows(2).map(|w| w[1] - w[0]).collect();
        assert_eq!(fired[0], 1, "healthy replicas probe every tick");
        assert_eq!(&gaps[..2], &[1, 2], "backoff did not reset: {gaps:?}");
    }

    #[test]
    fn health_epoch_bumps_only_on_transitions() {
        let m = Membership::new(&["a:1".into(), "b:2".into()]).unwrap();
        let r = &m.replicas[0];
        assert_eq!(r.epoch(), 0);
        m.mark_down(0);
        assert_eq!(r.epoch(), 1);
        m.mark_down(0); // already down: no transition
        assert_eq!(r.epoch(), 1);
        m.mark_healthy(0);
        assert_eq!(r.epoch(), 2);
        m.mark_healthy(0); // already healthy: no transition
        assert_eq!(r.epoch(), 2);
        // Other replicas are untouched.
        assert_eq!(m.replicas[1].epoch(), 0);
    }
}
