//! The fleet router: a consistent-hash ring over the replica set plus a
//! blocking forwarding proxy speaking the binary wire protocol on both
//! sides.
//!
//! Placement: the router decodes just enough of each predict request to
//! recompute the replica-side cache key (`CostSweep::of` fingerprint ×
//! target — the *identical* recipe `Coordinator::submit_to` uses, so a
//! request always lands on the replica whose LRU slice owns it), hashes
//! that key onto a ring of `vnodes` points per replica, and forwards the
//! original payload bytes verbatim to the first viable replica in
//! clockwise preference order.
//!
//! Viable = alive (see [`super::membership`]) and under the bounded-load
//! cap: an owner already carrying more than `load_factor ×` the fleet's
//! mean in-flight load sheds the request to the next alive successor
//! (consistent hashing with bounded loads — one hot fingerprint cannot
//! serialize its whole shard behind one replica). When a forward fails
//! mid-request the replica is marked down and the request retries on the
//! next alive successor — fail-open, no client-visible error; the
//! successor recomputes the prediction (a cache miss, not a wrong
//! answer, since every replica runs the same deterministic pipeline).
//! A request carrying a deadline extension budgets its own failover:
//! once the budget is spent the router answers with an explicit
//! deadline-expired error instead of retrying toward a reply every
//! replica would shed at admission anyway.
//!
//! Concurrency model: one blocking thread per client connection, each
//! owning its private downstream connections (created lazily per
//! replica, reused across requests). Routers front tens of client
//! connections, not the reactor's tens of thousands — thread-per-conn
//! keeps failover logic linear and testable.
//!
//! Sweeps (the multi-frame DSE verb) route by the *base* graph's
//! fingerprint, so the whole grid lands on the replica whose cache slice
//! owns the family the client is iterating on. The router relays the
//! replica's chunk stream verbatim; if the replica dies mid-stream it
//! re-issues the full sweep to the next alive successor and filters out
//! candidate indices the client already received (expansion is
//! deterministic, so the successor's terminal summary covers the full
//! grid) — fail-open, no client-visible error.

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cache::CacheKey;
use crate::simulator::CostSweep;
use crate::util::faults;
use crate::util::json::{Json, JsonObj};
use crate::util::rng::splitmix64;
use crate::wire::frame::{self, Decoded, FrameKind, DEFAULT_MAX_PAYLOAD};
use crate::wire::{codec, WireClient};
use crate::{log_info, log_warn};

use super::membership::{Membership, Replica};

/// Consistent-hash ring: `vnodes` pseudo-random points per replica on
/// the u64 circle, a key owned by the first point at or clockwise of its
/// hash. Deterministic across processes (splitmix64, no std hasher), so
/// every router instance and every test agrees on placement.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// Sorted (point, replica) pairs.
    points: Vec<(u64, u32)>,
    replicas: usize,
}

impl HashRing {
    pub fn new(replicas: usize, vnodes: usize) -> HashRing {
        assert!(replicas > 0, "ring needs at least one replica");
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(replicas * vnodes);
        for r in 0..replicas {
            for v in 0..vnodes {
                // Independent streams per replica; ties broken by index
                // so equal points cannot reorder between builds.
                let p = splitmix64(((r as u64) << 32) ^ v as u64 ^ 0xF1EE_7000);
                points.push((p, r as u32));
            }
        }
        points.sort_unstable();
        HashRing { points, replicas }
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Where a cache key lands on the circle.
    fn key_point(key: u128) -> u64 {
        // as_u128 is already avalanche-mixed; fold and re-mix so ring
        // position is independent of the LRU's own shard index (which
        // uses the high half directly).
        splitmix64((key as u64) ^ ((key >> 64) as u64).rotate_left(32))
    }

    /// The key's primary owner.
    pub fn owner(&self, key: u128) -> usize {
        self.preference(key)[0]
    }

    /// Every replica exactly once, in clockwise order from the key's
    /// point — the failover order.
    pub fn preference(&self, key: u128) -> Vec<usize> {
        let p = Self::key_point(key);
        let start = self.points.partition_point(|&(pt, _)| pt < p);
        let mut seen = vec![false; self.replicas];
        let mut order = Vec::with_capacity(self.replicas);
        for i in 0..self.points.len() {
            let (_, r) = self.points[(start + i) % self.points.len()];
            if !seen[r as usize] {
                seen[r as usize] = true;
                order.push(r as usize);
                if order.len() == self.replicas {
                    break;
                }
            }
        }
        order
    }

    /// Each replica's first ring point — a stable "position" label for
    /// `fleet_stats`.
    pub fn positions(&self) -> Vec<u64> {
        let mut pos = vec![u64::MAX; self.replicas];
        for &(p, r) in &self.points {
            let r = r as usize;
            if p < pos[r] {
                pos[r] = p;
            }
        }
        pos
    }
}

/// Router knobs (`--fleet router`).
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Downstream replica addresses (`--fleet-replicas a:1,b:2,...`).
    pub replicas: Vec<String>,
    /// Ring points per replica (`--fleet-vnodes`).
    pub vnodes: usize,
    /// Bounded-load factor (`--fleet-load-factor`): an owner above
    /// `load_factor × mean in-flight` sheds to the next alive successor.
    pub load_factor: f64,
    /// Health-probe cadence (`--fleet-health-interval-s`).
    pub health_interval: Duration,
    /// Per-frame payload ceiling (shared with the replica reactors).
    pub max_frame: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            replicas: Vec::new(),
            vnodes: 64,
            load_factor: 1.25,
            health_interval: Duration::from_secs(1),
            max_frame: DEFAULT_MAX_PAYLOAD,
        }
    }
}

struct Router {
    ring: HashRing,
    members: Arc<Membership>,
    cfg: RouterConfig,
}

/// Serve the fleet router forever on `addr`. `on_bound` receives the
/// bound port (bind port 0 in tests). Never returns except on bind
/// failure.
pub fn serve(addr: &str, cfg: RouterConfig, on_bound: impl FnOnce(u16)) -> Result<()> {
    let members = Membership::new(&cfg.replicas)?;
    members.spawn_prober(cfg.health_interval);
    let ring = HashRing::new(members.len(), cfg.vnodes);
    let listener = TcpListener::bind(addr)?;
    let port = listener.local_addr()?.port();
    log_info!(
        "dippm fleet router on port {port} ({} replicas, {} vnodes, load factor {})",
        members.len(),
        cfg.vnodes,
        cfg.load_factor
    );
    on_bound(port);
    let router = Arc::new(Router { ring, members, cfg });
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log_warn!("fleet router accept failed: {e}");
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        let router = router.clone();
        std::thread::Builder::new()
            .name("dippm-fleet-conn".into())
            .spawn(move || {
                let peer = stream
                    .peer_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| "?".into());
                if let Err(e) = handle_client(stream, &router) {
                    log_warn!("fleet client {peer}: {e:#}");
                }
            })
            .expect("spawn fleet connection thread");
    }
    Ok(())
}

/// One client connection: read frames, route/answer each, until EOF.
fn handle_client(mut stream: TcpStream, router: &Router) -> Result<()> {
    let _ = stream.set_nodelay(true);
    // Lazily-opened downstream connections, private to this client. Each
    // entry records the replica's health epoch at dial time: if the peer
    // bounced since (down → healthy), the pooled socket points at the
    // dead incarnation and is re-dialed instead of wasting a failover.
    let mut downstream: HashMap<usize, (u64, WireClient)> = HashMap::new();
    let mut rbuf: Vec<u8> = Vec::new();
    let mut chunk = vec![0u8; 64 * 1024];
    loop {
        let (kind, seq, payload, consumed) =
            match frame::decode(&rbuf, router.cfg.max_frame) {
                Ok(Decoded::Frame {
                    kind,
                    seq,
                    payload,
                    consumed,
                }) => (kind, seq, payload.to_vec(), consumed),
                Ok(Decoded::Incomplete) => {
                    let n = stream.read(&mut chunk)?;
                    if n == 0 {
                        return Ok(()); // clean EOF
                    }
                    rbuf.extend_from_slice(&chunk[..n]);
                    continue;
                }
                Err(e) => {
                    // Same discipline as the reactor: framing errors get
                    // one seq-0 error frame, then the connection closes.
                    let _ = stream.write_all(&frame::encode(
                        FrameKind::Error,
                        0,
                        e.to_string().as_bytes(),
                    ));
                    return Ok(());
                }
            };
        rbuf.drain(..consumed);
        if kind == FrameKind::SweepRequest {
            // Multi-frame exchange: the sweep handler owns the client
            // stream until the terminal frame is relayed.
            route_sweep(router, &mut downstream, &mut stream, seq, &payload)?;
            continue;
        }
        let (rkind, body) = answer(router, &mut downstream, kind, &payload);
        if rkind == FrameKind::Error && body == SERVER_ONLY {
            let _ = stream.write_all(&frame::encode(FrameKind::Error, 0, &body));
            return Ok(());
        }
        stream.write_all(&frame::encode(rkind, seq, &body))?;
    }
}

const SERVER_ONLY: &[u8] = b"client sent a server-only frame kind";

/// Route or answer one frame; returns the reply (kind, payload).
fn answer(
    router: &Router,
    downstream: &mut HashMap<usize, (u64, WireClient)>,
    kind: FrameKind,
    payload: &[u8],
) -> (FrameKind, Vec<u8>) {
    match kind {
        FrameKind::Request => route_request(router, downstream, payload),
        // Both stats verbs answer with the router's own document (echoing
        // the request's kind, so plain stats clients keep working): the
        // fleet is the unit an operator monitors here, and per-replica
        // cache stats stay one `shard_stats` hop away on each replica.
        FrameKind::Stats | FrameKind::FleetStats => {
            (kind, fleet_stats_json(router).into_bytes())
        }
        FrameKind::ShardStats | FrameKind::ManifestFetch | FrameKind::GenFetch => (
            FrameKind::Error,
            b"replication verbs are served by replicas, not the router".to_vec(),
        ),
        // Intercepted in handle_client before answer() — a sweep is a
        // multi-frame exchange and cannot return one reply here.
        FrameKind::SweepRequest => (
            FrameKind::Error,
            b"sweep requests are handled as a stream".to_vec(),
        ),
        FrameKind::Response
        | FrameKind::Error
        | FrameKind::Manifest
        | FrameKind::GenData
        | FrameKind::SweepChunk
        | FrameKind::SweepDone => (FrameKind::Error, SERVER_ONLY.to_vec()),
    }
}

/// Forward a predict request to the key's owner, shedding bounded-load
/// overflow and failing over past dead replicas.
fn route_request(
    router: &Router,
    downstream: &mut HashMap<usize, (u64, WireClient)>,
    payload: &[u8],
) -> (FrameKind, Vec<u8>) {
    // Recompute the replica's cache key: same fingerprint, same default
    // target policy. A payload the replica would reject is rejected here
    // with the same kind of request-level error. The deadline extension
    // (if any) also budgets the *failover loop*: retrying past the
    // client's deadline only produces an answer the replica would shed
    // anyway, so the router gives up first with an explicit error.
    let (key, deadline) = match codec::decode_request(payload) {
        Ok((graph, target, deadline_ms)) => (
            CacheKey::new(CostSweep::of(&graph).fingerprint, &target.unwrap_or_default()),
            deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms as u64)),
        ),
        Err(e) => return (FrameKind::Error, e.into_bytes()),
    };
    let order = router.ring.preference(key.as_u128());
    let members = &router.members;

    // Bounded load: the mean in-flight count across alive replicas,
    // scaled by the load factor, caps any single replica. `+1` keeps the
    // cap above zero on an idle fleet.
    let alive = members.alive_count().max(1);
    let cap = ((members.total_in_flight() as f64 / alive as f64) * router.cfg.load_factor)
        .ceil() as u64
        + 1;

    // Preference order, alive replicas only; over-cap owners drop behind
    // under-cap successors but stay as fallbacks.
    let mut candidates: Vec<usize> = Vec::with_capacity(order.len());
    let mut shed: Vec<usize> = Vec::new();
    for &i in &order {
        if !members.replicas[i].is_alive() {
            continue;
        }
        if members.replicas[i].in_flight.load(Ordering::Relaxed) < cap {
            candidates.push(i);
        } else {
            shed.push(i);
        }
    }
    candidates.extend(shed);
    if candidates.is_empty() {
        // Fail-open even past health state: probe order anyway rather
        // than erroring while the prober lags a replica's recovery.
        candidates = order.clone();
    }

    let owner = order[0];
    for (attempt, &i) in candidates.iter().enumerate() {
        // Deadline-budgeted failover: once the client's budget is spent,
        // further retries can only yield a reply the replica would shed
        // at admission. Shed here instead, with the attempt count.
        if deadline.is_some_and(|d| d <= Instant::now()) {
            return (
                FrameKind::Error,
                format!("deadline expired during fleet failover ({attempt} attempts made)")
                    .into_bytes(),
            );
        }
        let r = &members.replicas[i];
        if attempt == 0 {
            r.routed.fetch_add(1, Ordering::Relaxed);
        } else {
            r.retried.fetch_add(1, Ordering::Relaxed);
        }
        if i != owner {
            r.failed_over.fetch_add(1, Ordering::Relaxed);
        }
        r.in_flight.fetch_add(1, Ordering::Relaxed);
        let result = forward_once(downstream, i, r, payload);
        r.in_flight.fetch_sub(1, Ordering::Relaxed);
        match result {
            Ok(reply) => return reply,
            Err(e) => {
                // Transport failure: this replica is gone mid-request.
                // Drop its pooled connection, mark it down, try the next.
                downstream.remove(&i);
                members.mark_down(i);
                log_warn!("fleet forward to {} failed ({e:#}); failing over", r.addr);
            }
        }
    }
    (
        FrameKind::Error,
        b"no live replica for this shard".to_vec(),
    )
}

/// How one sweep forward attempt ended (`Err` = replica transport
/// failure, the caller fails over).
enum SweepOutcome {
    /// The replica's terminal frame (done summary or request-level error)
    /// was relayed to the client.
    Finished,
    /// The *client* connection failed mid-stream; abort, do not fail
    /// over (there is nobody left to stream to).
    ClientGone(anyhow::Error),
}

/// Forward a sweep to the base fingerprint's owner, relaying the chunk
/// stream and failing over past replicas that die mid-stream. `Err` =
/// the client connection itself failed (caller closes it).
fn route_sweep(
    router: &Router,
    downstream: &mut HashMap<usize, (u64, WireClient)>,
    stream: &mut TcpStream,
    seq: u32,
    payload: &[u8],
) -> Result<()> {
    // Placement: the *base* graph's cache key. Every candidate the sweep
    // expands shares the family's locality, so one replica's LRU slice
    // sees the whole grid (that is what makes the dedup + cache-hit path
    // effective across repeated sweeps).
    let (graph, target, _spec) = match codec::decode_sweep_request(payload) {
        Ok(t) => t,
        Err(e) => {
            stream.write_all(&frame::encode(FrameKind::Error, seq, e.as_bytes()))?;
            return Ok(());
        }
    };
    let key = CacheKey::new(CostSweep::of(&graph).fingerprint, &target.unwrap_or_default());
    let order = router.ring.preference(key.as_u128());
    let members = &router.members;
    let mut candidates: Vec<usize> = order
        .iter()
        .copied()
        .filter(|&i| members.replicas[i].is_alive())
        .collect();
    if candidates.is_empty() {
        // Fail-open past health state, same as route_request.
        candidates = order.clone();
    }
    let owner = order[0];
    // Candidate indices already streamed to the client: a failover
    // re-issues the whole sweep to the successor and filters these out
    // so the client never sees a duplicate item.
    let mut sent: HashSet<u32> = HashSet::new();
    for (attempt, &i) in candidates.iter().enumerate() {
        let r = &members.replicas[i];
        if attempt == 0 {
            r.routed.fetch_add(1, Ordering::Relaxed);
        } else {
            r.retried.fetch_add(1, Ordering::Relaxed);
        }
        if i != owner {
            r.failed_over.fetch_add(1, Ordering::Relaxed);
        }
        r.in_flight.fetch_add(1, Ordering::Relaxed);
        let result = forward_sweep_once(downstream, i, r, payload, seq, &mut sent, stream);
        r.in_flight.fetch_sub(1, Ordering::Relaxed);
        match result {
            Ok(SweepOutcome::Finished) => return Ok(()),
            Ok(SweepOutcome::ClientGone(e)) => return Err(e),
            Err(e) => {
                downstream.remove(&i);
                members.mark_down(i);
                log_warn!(
                    "fleet sweep forward to {} failed ({e:#}); failing over",
                    r.addr
                );
            }
        }
    }
    stream.write_all(&frame::encode(
        FrameKind::Error,
        seq,
        b"no live replica for this sweep",
    ))?;
    Ok(())
}

/// One sweep forward on the pooled downstream connection: relay chunk
/// frames (filtered against `sent`) under the client's seq until the
/// replica's terminal frame. `Err` = replica transport failure or
/// protocol violation (caller fails over).
fn forward_sweep_once(
    downstream: &mut HashMap<usize, (u64, WireClient)>,
    i: usize,
    r: &Replica,
    payload: &[u8],
    client_seq: u32,
    sent: &mut HashSet<u32>,
    stream: &mut TcpStream,
) -> Result<SweepOutcome> {
    if faults::fire("fleet:stall-peer") {
        downstream.remove(&i);
        anyhow::bail!("replica {} stalled (injected fault)", r.addr);
    }
    if let Some(spike) = faults::spike("fleet:slow-peer") {
        std::thread::sleep(spike);
    }
    let epoch = r.epoch();
    if matches!(downstream.get(&i), Some((e, _)) if *e != epoch) {
        downstream.remove(&i);
    }
    if !downstream.contains_key(&i) {
        downstream.insert(i, (epoch, WireClient::connect(&r.addr)?));
    }
    let (_, client) = downstream.get_mut(&i).expect("just inserted");
    let fwd_seq = client.send_raw(FrameKind::SweepRequest, payload)?;
    loop {
        let f = client.recv_frame()?;
        if f.kind == FrameKind::Error && f.seq == 0 {
            // Connection-level error: the replica is closing on us.
            anyhow::bail!(
                "replica {} closed mid-sweep: {}",
                r.addr,
                String::from_utf8_lossy(&f.payload)
            );
        }
        if f.seq != fwd_seq {
            anyhow::bail!(
                "replica {} answered seq {} for sweep seq {fwd_seq}",
                r.addr,
                f.seq
            );
        }
        match f.kind {
            FrameKind::SweepChunk => {
                let items = codec::decode_sweep_chunk(&f.payload)
                    .map_err(|e| anyhow::anyhow!("bad sweep chunk from {}: {e}", r.addr))?;
                let fresh: Vec<_> =
                    items.into_iter().filter(|it| sent.insert(it.index)).collect();
                if fresh.is_empty() {
                    continue; // a failover retread — everything already sent
                }
                let body = codec::encode_sweep_chunk(&fresh);
                if let Err(e) =
                    stream.write_all(&frame::encode(FrameKind::SweepChunk, client_seq, &body))
                {
                    return Ok(SweepOutcome::ClientGone(e.into()));
                }
            }
            // Terminal frames relay as-is: the done summary covers the
            // full grid (expansion is deterministic on every replica),
            // and a request-level error ends the sweep for the client.
            FrameKind::SweepDone | FrameKind::Error => {
                if let Err(e) = stream.write_all(&frame::encode(f.kind, client_seq, &f.payload)) {
                    return Ok(SweepOutcome::ClientGone(e.into()));
                }
                return Ok(SweepOutcome::Finished);
            }
            other => anyhow::bail!(
                "unexpected frame kind {other:?} in sweep stream from {}",
                r.addr
            ),
        }
    }
}

/// One forward on the pooled downstream connection: send the original
/// request payload under this connection's own seq, wait for its reply.
/// `Err` = transport failure (caller fails over); a request-level error
/// from the replica is a successful forward and flows back to the client.
///
/// Pool hygiene: the entry is keyed by the replica's health epoch at
/// dial time. A replica that went down and recovered bumps its epoch
/// twice, so the stale socket (connected to the dead incarnation) is
/// evicted and re-dialed here rather than discovered the hard way as a
/// mid-request write error and an unnecessary failover.
fn forward_once(
    downstream: &mut HashMap<usize, (u64, WireClient)>,
    i: usize,
    r: &Replica,
    payload: &[u8],
) -> Result<(FrameKind, Vec<u8>)> {
    if faults::fire("fleet:stall-peer") {
        // A wedged peer never answers; surface it as a transport failure
        // so the request fails over instead of hanging the client.
        downstream.remove(&i);
        anyhow::bail!("replica {} stalled (injected fault)", r.addr);
    }
    if let Some(spike) = faults::spike("fleet:slow-peer") {
        std::thread::sleep(spike);
    }
    let epoch = r.epoch();
    if matches!(downstream.get(&i), Some((e, _)) if *e != epoch) {
        downstream.remove(&i);
    }
    if !downstream.contains_key(&i) {
        downstream.insert(i, (epoch, WireClient::connect(&r.addr)?));
    }
    let (_, client) = downstream.get_mut(&i).expect("just inserted");
    let seq = client.send_raw(FrameKind::Request, payload)?;
    let f = client.recv_frame()?;
    if f.seq != seq && f.seq != 0 {
        anyhow::bail!(
            "replica {} answered seq {} for request seq {seq}",
            r.addr,
            f.seq
        );
    }
    Ok((f.kind, f.payload))
}

/// The `fleet_stats` document: ring layout + per-replica health and
/// routing counters.
fn fleet_stats_json(router: &Router) -> String {
    let positions = router.ring.positions();
    let mut o = JsonObj::new();
    o.insert("ok", true);
    o.insert("fleet", "router");
    o.insert("replicas", router.members.len());
    o.insert("alive", router.members.alive_count());
    o.insert("vnodes", router.cfg.vnodes);
    o.insert("load_factor", router.cfg.load_factor);
    let rows: Vec<Json> = router
        .members
        .replicas
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut row = JsonObj::new();
            row.insert("addr", r.addr.as_str());
            row.insert("alive", r.is_alive());
            // First ring point, as a stable position label (hex keeps
            // the u64 exact; JSON numbers are f64).
            row.insert("ring_position", format!("{:016x}", positions[i]));
            row.insert("routed", r.routed.load(Ordering::Relaxed) as usize);
            row.insert("retried", r.retried.load(Ordering::Relaxed) as usize);
            row.insert("failed_over", r.failed_over.load(Ordering::Relaxed) as usize);
            row.insert("in_flight", r.in_flight.load(Ordering::Relaxed) as usize);
            Json::Obj(row)
        })
        .collect();
    o.insert("replica_stats", Json::Arr(rows));
    Json::Obj(o).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic-but-realistic keys: avalanche-mixed like CacheKey.
    fn keys(n: u64) -> impl Iterator<Item = u128> {
        (0..n).map(|i| {
            let lo = splitmix64(i ^ 0xA5A5_0001);
            let hi = splitmix64(i ^ 0x5A5A_0002);
            ((hi as u128) << 64) | lo as u128
        })
    }

    #[test]
    fn ring_balance_is_bounded() {
        // 10k synthetic fingerprints over 8 replicas × 128 vnodes: the
        // fullest shard stays within 2x the emptiest. Deterministic —
        // the ring and the keys both come from splitmix64 streams.
        let ring = HashRing::new(8, 128);
        let mut owned = vec![0u64; 8];
        for k in keys(10_000) {
            owned[ring.owner(k)] += 1;
        }
        let max = *owned.iter().max().unwrap();
        let min = *owned.iter().min().unwrap();
        assert!(min > 0, "a replica owns nothing: {owned:?}");
        let ratio = max as f64 / min as f64;
        assert!(ratio <= 2.0, "load ratio {ratio:.2} too lopsided: {owned:?}");
    }

    #[test]
    fn ring_join_moves_few_keys() {
        // Adding a 10th replica to a 9-replica ring must remap roughly
        // 1/10 of keys — and only *to* the joiner, never between
        // incumbents (the whole point of consistent hashing).
        let before = HashRing::new(9, 128);
        let after = HashRing::new(10, 128);
        let total = 10_000u64;
        let mut moved = 0u64;
        for k in keys(total) {
            let a = before.owner(k);
            let b = after.owner(k);
            if a != b {
                moved += 1;
                assert_eq!(b, 9, "key moved between incumbents: {a} -> {b}");
            }
        }
        let frac = moved as f64 / total as f64;
        assert!(
            frac > 0.02 && frac <= 2.0 / 10.0,
            "join remapped {frac:.3} of keys (want ~1/10)"
        );
    }

    #[test]
    fn ring_leave_moves_only_the_leavers_keys() {
        // A dead replica's keys spill to its clockwise successors; every
        // other key keeps its owner. Failover uses preference order, so
        // "the ring with replica 3 dead" = skip 3 in preference.
        let ring = HashRing::new(6, 128);
        let dead = 3usize;
        let mut spilled = 0u64;
        let total = 10_000u64;
        for k in keys(total) {
            let order = ring.preference(k);
            let with_dead: usize = *order.iter().find(|&&r| r != dead).unwrap();
            if order[0] == dead {
                spilled += 1;
            } else {
                assert_eq!(order[0], with_dead, "live key changed owner");
            }
        }
        let frac = spilled as f64 / total as f64;
        assert!(
            frac > 0.05 && frac <= 2.0 / 6.0,
            "leave spilled {frac:.3} of keys (want ~1/6)"
        );
    }

    #[test]
    fn preference_is_a_permutation() {
        let ring = HashRing::new(5, 32);
        for k in keys(100) {
            let mut p = ring.preference(k);
            assert_eq!(p[0], ring.owner(k));
            p.sort_unstable();
            assert_eq!(p, vec![0, 1, 2, 3, 4]);
        }
    }

    #[test]
    fn ring_is_deterministic_across_builds() {
        let a = HashRing::new(4, 64);
        let b = HashRing::new(4, 64);
        for k in keys(500) {
            assert_eq!(a.owner(k), b.owner(k));
        }
        assert_eq!(a.positions(), b.positions());
    }
}
