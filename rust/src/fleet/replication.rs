//! Manifest-based cache replication: warm-start a replica from a peer's
//! persistence store instead of recomputing predictions.
//!
//! The persistence layer (PR 4) already gives every replica exactly the
//! artifact replication needs: an atomically-swapped `MANIFEST` naming a
//! committed generation plus, per shard, the generation file's byte
//! length and whole-file checksum. Replication is therefore file
//! shipping, not entry shipping:
//!
//! 1. `ManifestFetch` → the peer's validated `MANIFEST` bytes.
//! 2. `GenFetch(generation, shard)` per non-empty manifest record → the
//!    raw `gen-<G>-shard-<S>.bin` bytes.
//! 3. [`crate::cache::persist::import_store`] verifies every file
//!    against its manifest record (exact length + checksum) and
//!    assembles a bootable store directory, committing the `MANIFEST`
//!    last — a crash mid-import leaves nothing a boot would trust.
//!
//! The caller then loads that directory like any other store
//! (`Coordinator::load_cache`), which counts the entries as
//! `warm_start_entries`. Journal tails are deliberately not shipped:
//! the manifest names only compacted state, and the peer's tail keeps
//! changing under load — compact before replicating when freshness
//! matters (the warm-start test does exactly that).

use std::path::Path;

use anyhow::{Context, Result};

use crate::cache::persist::{self, ImportReport};
use crate::log_info;
use crate::wire::WireClient;

/// Fetch `peer_addr`'s committed store into `dest` and verify it
/// end-to-end. `dest` need not exist; an existing store there is
/// overwritten shard-by-shard (the manifest swap is last, so readers
/// never observe a half-imported generation).
pub fn replicate_from_peer(peer_addr: &str, dest: &Path) -> Result<ImportReport> {
    let mut client = WireClient::connect(peer_addr)
        .with_context(|| format!("connecting to fleet peer {peer_addr}"))?;
    let manifest = client
        .fetch_manifest()
        .with_context(|| format!("fetching manifest from {peer_addr}"))?;
    let m = persist::decode_manifest(&manifest)?;
    let mut shard_files = Vec::new();
    for (i, rec) in m.shards.iter().enumerate() {
        if rec.len == 0 {
            continue; // no base file for this shard
        }
        let bytes = client
            .fetch_gen_shard(m.generation, i as u32)
            .with_context(|| {
                format!("fetching generation {} shard {i} from {peer_addr}", m.generation)
            })?;
        shard_files.push((i, bytes));
    }
    let report = persist::import_store(dest, &manifest, &shard_files)?;
    log_info!(
        "replicated generation {} from {peer_addr}: {} shard files, {} bytes -> {}",
        report.generation,
        report.shards_written,
        report.bytes,
        dest.display()
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::persist::{import_store, manifest_bytes, write_fresh_store};
    use std::time::Duration;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dippm-fleet-repl-{}-{name}", std::process::id()))
    }

    /// The wire-free core: export a store's manifest + gen files, import
    /// them elsewhere, boot the copy, get the same entries back.
    #[test]
    fn export_import_roundtrip_is_bootable() {
        let src = tmp_dir("src");
        let dst = tmp_dir("dst");
        let _ = std::fs::remove_dir_all(&src);
        let _ = std::fs::remove_dir_all(&dst);
        let entries: Vec<(u128, u32, Duration)> = (0..200u32)
            .map(|i| ((i as u128) << 64 | i as u128, i, Duration::ZERO))
            .collect();
        write_fresh_store(&src, entries.clone(), 4, 2).unwrap();

        let manifest = manifest_bytes(&src).unwrap();
        let m = persist::decode_manifest(&manifest).unwrap();
        let mut shard_files = Vec::new();
        for (i, rec) in m.shards.iter().enumerate() {
            if rec.len > 0 {
                shard_files.push((i, persist::gen_shard_bytes(&src, m.generation, i).unwrap()));
            }
        }
        let report = import_store(&dst, &manifest, &shard_files).unwrap();
        assert_eq!(report.generation, m.generation);
        assert_eq!(report.shards_written, shard_files.len());

        let boot = persist::read_store::<u32>(&dst).unwrap();
        let mut got: Vec<(u128, u32)> =
            boot.base.into_iter().map(|(k, v, _)| (k, v)).collect();
        got.sort_unstable();
        let mut want: Vec<(u128, u32)> =
            entries.into_iter().map(|(k, v, _)| (k, v)).collect();
        want.sort_unstable();
        assert_eq!(got, want);
        let _ = std::fs::remove_dir_all(&src);
        let _ = std::fs::remove_dir_all(&dst);
    }

    #[test]
    fn import_rejects_tampered_shards() {
        let src = tmp_dir("tamper-src");
        let dst = tmp_dir("tamper-dst");
        let _ = std::fs::remove_dir_all(&src);
        let _ = std::fs::remove_dir_all(&dst);
        let entries: Vec<(u128, u32, Duration)> =
            (0..50u32).map(|i| (i as u128, i, Duration::ZERO)).collect();
        write_fresh_store(&src, entries, 2, 1).unwrap();
        let manifest = manifest_bytes(&src).unwrap();
        let m = persist::decode_manifest(&manifest).unwrap();
        let mut shard_files = Vec::new();
        for (i, rec) in m.shards.iter().enumerate() {
            if rec.len > 0 {
                shard_files.push((i, persist::gen_shard_bytes(&src, m.generation, i).unwrap()));
            }
        }
        // Flip one byte in the first shipped file: checksum mismatch.
        let mut bad = shard_files.clone();
        bad[0].1[20] ^= 0xFF;
        let err = import_store(&dst, &manifest, &bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error: {err}");
        // Nothing committed: no MANIFEST in dest.
        assert!(!dst.join("MANIFEST").exists());

        // Truncation is caught by the length record.
        let mut short = shard_files.clone();
        short[0].1.pop();
        let err = import_store(&dst, &manifest, &short).unwrap_err().to_string();
        assert!(err.contains("length"), "unexpected error: {err}");

        // A missing non-empty shard is refused outright.
        let err = import_store(&dst, &manifest, &shard_files[1..])
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing"), "unexpected error: {err}");
        let _ = std::fs::remove_dir_all(&src);
        let _ = std::fs::remove_dir_all(&dst);
    }
}
