//! Fleet mode: N coordinator replicas behind a consistent-hash router.
//!
//! One process is the scale ceiling PRs 3–6 left standing: a single LRU
//! caps the hot set and a single reactor caps aggregate throughput. The
//! graph-fingerprint cache key already makes placement trivial — it is
//! deterministic across processes (`CacheKey::as_u128`), so hashing it
//! onto a ring of replicas gives each replica a disjoint cache slice and
//! aggregate capacity/throughput that scales ~linearly in replica count.
//!
//! Three pieces, one per module:
//!
//! * [`router`] — a consistent-hash ring (virtual nodes + bounded-load
//!   balancing) and the router process: it accepts binary-protocol
//!   clients, peeks just far enough into each predict request to compute
//!   the cache key, and forwards the frame verbatim to the owning
//!   replica, failing over clockwise to the next live peer when a shard
//!   is down.
//! * [`membership`] — the static `--fleet-replicas` list plus per-replica
//!   health state: a replica is marked down the instant a forward fails,
//!   and a background prober with per-replica exponential backoff brings
//!   it back once it answers again.
//! * [`replication`] — manifest-based cache replication: every replica
//!   serves its persistence store's `MANIFEST` (generation id + per-shard
//!   byte length + checksum) and raw generation files over the
//!   `ManifestFetch`/`GenFetch` wire verbs, so a cold-booting or
//!   rebalancing replica fetches a peer's warm-start generation files
//!   instead of recomputing predictions.
//!
//! Everything is hermetically testable with SimBackend replicas on
//! localhost: see `tests/fleet.rs` and the `fleet_scaling` bench.

pub mod membership;
pub mod replication;
pub mod router;

pub use membership::{Membership, Replica, ReplicaHealth};
pub use replication::replicate_from_peer;
pub use router::{HashRing, RouterConfig};
