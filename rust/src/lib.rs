//! # DIPPM — Deep Learning Inference Performance Predictive Model
//!
//! Full-system reproduction of *"DIPPM: a Deep Learning Inference Performance
//! Predictive Model using Graph Neural Networks"* (Panner Selvam & Brorsson,
//! 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (fused GraphSAGE layer, fused FC block) authored
//!   in `python/compile/kernels/`, AOT-lowered to HLO text.
//! * **L2** — the PMGNS model + Table-4 baselines (GCN/GIN/GAT/MLP) in JAX,
//!   with Huber loss and the Adam update lowered *into* the train-step HLO.
//! * **L3** — this crate: the generalized graph IR, the four framework
//!   frontends, the ten model-family generators, the A100 device simulator
//!   (ground-truth substrate), featurization (Algorithm 1 + eq. 1), the
//!   dataset pipeline, the PJRT runtime, the training driver, the serving
//!   coordinator with its graph-fingerprint prediction cache, and the MIG
//!   advisor.
//!
//! Python never runs on the request path: after `make artifacts` the `dippm`
//! binary is self-contained. See `rust/README.md` for the three-layer
//! architecture, the serving-cache subsystem (`cache/`) and how the offline
//! vendor crates relate to the real PJRT bindings.

pub mod cache;
pub mod coordinator;
pub mod dataset;
pub mod features;
pub mod fleet;
pub mod frontends;
pub mod ir;
pub mod mig;
pub mod modelgen;
pub mod runtime;
pub mod simulator;
pub mod training;
pub mod util;
pub mod wire;
