//! Per-op arithmetic cost: FLOPs, MACs and HBM bytes from shapes.
//!
//! MACs follow TVM's relay.analysis.count_macs convention (only conv /
//! dense / batch_matmul count — paper §3.3); FLOPs are the full roofline
//! work estimate used by the device model, and bytes are the ideal HBM
//! traffic of an unfused kernel (inputs + weights + outputs), scaled by
//! each tensor's element dtype: inputs are priced at their *producer's*
//! dtype, weights and outputs at the node's own dtype. All-fp32 graphs
//! (the implicit default) cost exactly what the pre-dtype model charged.

use crate::ir::infer::numel;
use crate::ir::{Graph, Node, OpKind};

/// Legacy fp32 element width — still the byte width of every default-dtype
/// tensor (`DType::F32.bytes()` returns exactly this).
pub const BYTES_PER_ELEM: f64 = 4.0;

/// Byte width of `node`'s output (and weight) elements.
pub fn node_elem_bytes(node: &Node) -> f64 {
    node.attrs.dtype.bytes()
}

/// Cost of one node in isolation (before fusion).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCost {
    pub flops: f64,
    pub macs: f64,
    pub bytes_in: f64,
    pub bytes_weights: f64,
    pub bytes_out: f64,
}

impl OpCost {
    pub fn total_bytes(&self) -> f64 {
        self.bytes_in + self.bytes_weights + self.bytes_out
    }
}

/// Compute the cost of `node` within `graph`.
pub fn op_cost(graph: &Graph, node: &Node) -> OpCost {
    let in_numel: f64 = node
        .inputs
        .iter()
        .map(|&i| numel(&graph.nodes[i].out_shape) as f64)
        .sum();
    // Input bytes at each producer's dtype (a concat of fp16 tensors reads
    // fp16 bytes even if this node is typed differently).
    let in_bytes: f64 = node
        .inputs
        .iter()
        .map(|&i| {
            let p = &graph.nodes[i];
            numel(&p.out_shape) as f64 * node_elem_bytes(p)
        })
        .sum();
    let elem = node_elem_bytes(node);
    let out_numel = numel(&node.out_shape) as f64;
    let first_in = node
        .inputs
        .first()
        .map(|&i| graph.nodes[i].out_shape.as_slice())
        .unwrap_or(&[]);

    let mut c = OpCost {
        bytes_in: in_bytes,
        bytes_out: out_numel * elem,
        ..Default::default()
    };

    match node.op {
        OpKind::Input => {
            c.bytes_in = 0.0;
            c.bytes_out = 0.0; // materialized by the host copy, not a kernel
        }
        OpKind::Conv2d | OpKind::Conv2dTranspose => {
            let (kh, kw) = node.attrs.kernel.unwrap_or((1, 1));
            let c_in = first_in.get(1).copied().unwrap_or(1) as f64;
            let groups = node.attrs.groups.max(1) as f64;
            // out elems * (C_in/g * kh * kw) MACs each
            c.macs = out_numel * (c_in / groups) * (kh * kw) as f64;
            c.flops = 2.0 * c.macs;
            let c_out = node.out_shape.get(1).copied().unwrap_or(1) as f64;
            c.bytes_weights = (c_out * (c_in / groups) * (kh * kw) as f64 + c_out) * elem;
        }
        OpKind::DepthwiseConv2d => {
            let (kh, kw) = node.attrs.kernel.unwrap_or((1, 1));
            c.macs = out_numel * (kh * kw) as f64;
            c.flops = 2.0 * c.macs;
            let ch = first_in.get(1).copied().unwrap_or(1) as f64;
            c.bytes_weights = (ch * (kh * kw) as f64 + ch) * elem;
        }
        OpKind::Dense => {
            let d_in = *first_in.last().unwrap_or(&1) as f64;
            c.macs = out_numel * d_in;
            c.flops = 2.0 * c.macs;
            let d_out = *node.out_shape.last().unwrap_or(&1) as f64;
            c.bytes_weights = (d_in * d_out + d_out) * elem;
        }
        OpKind::BatchMatmul => {
            // [B,M,K] x [B,K,N]: B*M*N*K MACs
            let k = *first_in.last().unwrap_or(&1) as f64;
            c.macs = out_numel * k;
            c.flops = 2.0 * c.macs;
        }
        OpKind::Relu => c.flops = out_numel,
        OpKind::Sigmoid | OpKind::HardSwish => c.flops = 4.0 * out_numel,
        OpKind::Gelu => c.flops = 8.0 * out_numel,
        OpKind::Softmax => c.flops = 5.0 * out_numel,
        OpKind::Add | OpKind::Multiply => c.flops = out_numel,
        OpKind::Concat => c.flops = 0.0, // pure data movement
        OpKind::MaxPool2d | OpKind::AvgPool2d => {
            let (kh, kw) = node.attrs.kernel.unwrap_or((1, 1));
            c.flops = out_numel * (kh * kw) as f64;
        }
        OpKind::GlobalAvgPool2d | OpKind::Mean => c.flops = in_numel,
        OpKind::BatchNorm => {
            c.flops = 2.0 * out_numel; // folded scale+shift at inference
            let ch = first_in.get(1).copied().unwrap_or(1) as f64;
            c.bytes_weights = 2.0 * ch * elem;
        }
        OpKind::LayerNorm => {
            c.flops = 8.0 * out_numel;
            let d = *first_in.last().unwrap_or(&1) as f64;
            c.bytes_weights = 2.0 * d * elem;
        }
        OpKind::Reshape | OpKind::Flatten => {
            // Metadata-only on contiguous tensors.
            c.flops = 0.0;
            c.bytes_in = 0.0;
            c.bytes_out = 0.0;
        }
        OpKind::Transpose | OpKind::StridedSlice => c.flops = 0.0, // move-only
    }
    c
}

/// Total MACs of a graph (the SFG's F_mac, paper eq. 1 — TVM convention:
/// only ops with `counts_macs`).
pub fn total_macs(graph: &Graph) -> f64 {
    graph
        .nodes
        .iter()
        .filter(|n| n.op.counts_macs())
        .map(|n| op_cost(graph, n).macs)
        .sum()
}

/// Total FLOPs of a graph (all ops).
pub fn total_flops(graph: &Graph) -> f64 {
    graph.nodes.iter().map(|n| op_cost(graph, n).flops).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Attrs, GraphBuilder};

    #[test]
    fn conv_macs_match_formula() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 3, 32, 32]);
        b.conv2d(x, 16, 3, 1, 1);
        let g = b.finish();
        let conv = &g.nodes[1];
        let c = op_cost(&g, conv);
        // out 16x32x32, each needs 3*3*3 MACs
        assert_eq!(c.macs, (16 * 32 * 32) as f64 * 27.0);
        assert_eq!(c.flops, 2.0 * c.macs);
        assert_eq!(c.bytes_weights, ((16 * 3 * 9 + 16) as f64) * 4.0);
    }

    #[test]
    fn dense_macs() {
        let mut b = GraphBuilder::new("t", "t", 2);
        let x = b.input(vec![2, 128]);
        b.dense(x, 10);
        let g = b.finish();
        let c = op_cost(&g, &g.nodes[1]);
        assert_eq!(c.macs, (2 * 10 * 128) as f64);
    }

    #[test]
    fn depthwise_cheaper_than_dense_conv() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 32, 16, 16]);
        let dw = b.depthwise(x, 3, 1, 1);
        let _cv = b.conv2d(dw, 32, 3, 1, 1);
        let g = b.finish();
        let dwc = op_cost(&g, &g.nodes[1]);
        let cvc = op_cost(&g, &g.nodes[2]);
        assert!(dwc.macs * 8.0 < cvc.macs);
    }

    #[test]
    fn total_macs_ignores_elementwise() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 3, 8, 8]);
        let c = b.conv_relu(x, 4, 3, 1, 1);
        let _ = b.relu(c);
        let g = b.finish();
        let conv_only = op_cost(&g, &g.nodes[1]).macs;
        assert_eq!(total_macs(&g), conv_only);
        assert!(total_flops(&g) > 2.0 * conv_only);
    }

    #[test]
    fn dtype_scales_bytes_not_flops() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 3, 32, 32]);
        b.conv2d(x, 16, 3, 1, 1);
        let g = b.finish();
        let f16 = crate::ir::quantize::quantize(&g, crate::ir::DType::F16);
        let i8g = crate::ir::quantize::quantize(&g, crate::ir::DType::I8);
        let c32 = op_cost(&g, &g.nodes[1]);
        let c16 = op_cost(&f16, &f16.nodes[1]);
        let c8 = op_cost(&i8g, &i8g.nodes[1]);
        assert_eq!(c16.flops, c32.flops);
        assert_eq!(c16.macs, c32.macs);
        assert_eq!(c16.total_bytes(), c32.total_bytes() / 2.0);
        assert_eq!(c8.total_bytes(), c32.total_bytes() / 4.0);
    }

    #[test]
    fn reshape_is_free() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 4, 2, 2]);
        let f = b.add(OpKind::Flatten, Attrs::none(), &[x]);
        let g = b.finish();
        let c = op_cost(&g, &g.nodes[f]);
        assert_eq!(c.flops, 0.0);
        assert_eq!(c.total_bytes(), 0.0);
    }
}
