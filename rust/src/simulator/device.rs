//! Device model: NVIDIA A100-SXM4-40GB and its MIG partitions.
//!
//! Numbers are from the A100 datasheet / MIG user guide; the utilization
//! half-work constants are calibration knobs (DESIGN.md §6) that shape the
//! small-kernel inefficiency the paper's GNN learns to capture.

/// A MIG profile of the A100 (paper §3.5 considers these four).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MigProfile {
    /// 1g.5gb — 1/7 of SMs, 1/8 of memory bandwidth, 5 GB.
    G1_5,
    /// 2g.10gb
    G2_10,
    /// 3g.20gb
    G3_20,
    /// 7g.40gb — the full GPU (what the paper's dataset was measured on).
    G7_40,
}

pub const ALL_PROFILES: [MigProfile; 4] = [
    MigProfile::G1_5,
    MigProfile::G2_10,
    MigProfile::G3_20,
    MigProfile::G7_40,
];

impl MigProfile {
    pub fn name(self) -> &'static str {
        match self {
            MigProfile::G1_5 => "1g.5gb",
            MigProfile::G2_10 => "2g.10gb",
            MigProfile::G3_20 => "3g.20gb",
            MigProfile::G7_40 => "7g.40gb",
        }
    }

    pub fn from_name(s: &str) -> Option<MigProfile> {
        ALL_PROFILES.iter().copied().find(|p| p.name() == s)
    }

    /// Fraction of the 108 SMs (GPU slices are out of 7).
    pub fn sm_fraction(self) -> f64 {
        match self {
            MigProfile::G1_5 => 1.0 / 7.0,
            MigProfile::G2_10 => 2.0 / 7.0,
            MigProfile::G3_20 => 3.0 / 7.0,
            MigProfile::G7_40 => 1.0,
        }
    }

    /// Fraction of HBM bandwidth (memory slices are out of 8).
    pub fn bw_fraction(self) -> f64 {
        match self {
            MigProfile::G1_5 => 1.0 / 8.0,
            MigProfile::G2_10 => 2.0 / 8.0,
            MigProfile::G3_20 => 4.0 / 8.0,
            MigProfile::G7_40 => 1.0,
        }
    }

    /// Memory capacity in MB.
    pub fn capacity_mb(self) -> f64 {
        match self {
            MigProfile::G1_5 => 5.0 * 1024.0,
            MigProfile::G2_10 => 10.0 * 1024.0,
            MigProfile::G3_20 => 20.0 * 1024.0,
            MigProfile::G7_40 => 40.0 * 1024.0,
        }
    }
}

/// Calibrated A100 device parameters used by the analytical cost model.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Peak tensor-core throughput for the FP32-input (TF32) path, FLOP/s.
    pub tc_flops: f64,
    /// Peak CUDA-core FP32 throughput, FLOP/s.
    pub cuda_flops: f64,
    /// Peak HBM bandwidth, B/s.
    pub hbm_bw: f64,
    /// Kernel launch + scheduling overhead per (fused) kernel, seconds.
    pub launch_s: f64,
    /// Max achievable utilization of peak compute (cuDNN-style efficiency).
    pub max_compute_util: f64,
    /// Max achievable fraction of peak bandwidth.
    pub max_bw_util: f64,
    /// FLOPs at which compute utilization reaches half of max.
    pub flops_half_util: f64,
    /// Bytes at which bandwidth utilization reaches half of max.
    pub bytes_half_util: f64,
    /// Idle board power (W) attributed while a kernel runs at util ~ 0.
    pub idle_w: f64,
    /// TDP (W) at full utilization.
    pub tdp_w: f64,
    /// CUDA context + framework baseline memory (MB) on the full GPU.
    pub context_mb: f64,
    /// Allocator slack multiplier on activations (caching allocator).
    pub alloc_slack: f64,
    /// cuDNN/cuBLAS workspace pool floor (MB).
    pub workspace_floor_mb: f64,
}

impl Default for DeviceSpec {
    fn default() -> Self {
        DeviceSpec {
            tc_flops: 156e12,  // TF32 tensor core
            cuda_flops: 19.5e12,
            hbm_bw: 1555e9,
            launch_s: 4e-6,
            max_compute_util: 0.62,
            max_bw_util: 0.78,
            flops_half_util: 6.0e8,
            bytes_half_util: 1.2e7,
            idle_w: 58.0,
            tdp_w: 400.0,
            context_mb: 1045.0,
            alloc_slack: 1.32,
            workspace_floor_mb: 192.0,
        }
    }
}

impl DeviceSpec {
    /// Compute-utilization saturation curve: util(w) = umax * w / (w + w50).
    pub fn compute_util(&self, flops: f64) -> f64 {
        self.max_compute_util * flops / (flops + self.flops_half_util)
    }

    pub fn bw_util(&self, bytes: f64) -> f64 {
        self.max_bw_util * bytes / (bytes + self.bytes_half_util)
    }

    /// Peak tensor-core throughput at `dtype` (FLOP/s or OP/s). fp32 runs
    /// the TF32 path at exactly `tc_flops` — the pre-dtype value — while
    /// fp16/bf16 double it (312 TFLOPS on the datasheet) and int8
    /// quadruples it (624 TOPS).
    pub fn tc_flops_at(&self, dtype: crate::ir::DType) -> f64 {
        self.tc_flops * dtype.throughput_scale()
    }

    /// Peak CUDA-core throughput at `dtype`. fp16 doubles the fp32 rate
    /// (packed half2 math); bf16/int8 on CUDA cores see the same 2x/4x
    /// packing win as the tensor-core path.
    pub fn cuda_flops_at(&self, dtype: crate::ir::DType) -> f64 {
        self.cuda_flops * dtype.throughput_scale()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_names_roundtrip() {
        for p in ALL_PROFILES {
            assert_eq!(MigProfile::from_name(p.name()), Some(p));
        }
        assert_eq!(MigProfile::from_name("9g.80gb"), None);
    }

    #[test]
    fn fractions_monotone() {
        let sm: Vec<f64> = ALL_PROFILES.iter().map(|p| p.sm_fraction()).collect();
        let bw: Vec<f64> = ALL_PROFILES.iter().map(|p| p.bw_fraction()).collect();
        assert!(sm.windows(2).all(|w| w[0] < w[1]));
        assert!(bw.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(MigProfile::G7_40.sm_fraction(), 1.0);
    }

    #[test]
    fn dtype_throughput_tiers() {
        use crate::ir::DType;
        let d = DeviceSpec::default();
        assert_eq!(d.tc_flops_at(DType::F32), d.tc_flops);
        assert_eq!(d.cuda_flops_at(DType::F32), d.cuda_flops);
        assert_eq!(d.tc_flops_at(DType::F16), 2.0 * d.tc_flops);
        assert_eq!(d.tc_flops_at(DType::BF16), 2.0 * d.tc_flops);
        assert_eq!(d.tc_flops_at(DType::I8), 4.0 * d.tc_flops);
    }

    #[test]
    fn util_curves_saturate() {
        let d = DeviceSpec::default();
        assert!(d.compute_util(1e3) < 0.01);
        assert!(d.compute_util(1e12) > 0.6 * d.max_compute_util);
        assert!(d.compute_util(1e15) < d.max_compute_util);
        assert!(d.bw_util(1e12) > 0.7 * d.max_bw_util);
    }
}
