//! Memory model: peak device-memory consumption of an inference pass.
//!
//! peak = context + weights + alloc_slack * (liveness-peak activations)
//!        + workspace, where the liveness peak comes from walking the graph
//! in execution order and freeing each tensor after its last consumer —
//! what a framework's caching allocator converges to. The workspace term
//! models cuDNN algorithm scratch (proportional to the largest conv) with a
//! pool floor. Mirrors the out-of-memory failure mode Gao et al. report
//! (paper §1) and reproduces the Fig. 3 profile-capacity effect via the
//! context scaling in `Simulator::memory_mb`.

use crate::ir::infer::{numel, weight_count};
use crate::ir::{Graph, OpKind};

use super::cost::node_elem_bytes;

/// Peak live activation bytes over a topological execution of the graph.
pub fn peak_activation_bytes(graph: &Graph) -> f64 {
    let consumers = graph.consumers();
    // last_use[i] = position of the last consumer of node i (or its own
    // position if unconsumed — outputs stay alive to the end of the pass).
    let n = graph.nodes.len();
    let mut last_use: Vec<usize> = (0..n).collect();
    for (i, node) in graph.nodes.iter().enumerate() {
        for &src in &node.inputs {
            last_use[src] = last_use[src].max(i);
        }
    }
    for (i, cons) in consumers.iter().enumerate() {
        if cons.is_empty() {
            last_use[i] = n; // graph output lives until the pass ends
        }
    }
    // Alias propagation: a reshape/flatten shares its input's buffer, so
    // the input must stay live as long as the alias is (reverse pass
    // handles alias chains).
    for i in (0..n).rev() {
        let node = &graph.nodes[i];
        if matches!(node.op, OpKind::Reshape | OpKind::Flatten) {
            if let Some(&p) = node.inputs.first() {
                last_use[p] = last_use[p].max(last_use[i]);
            }
        }
    }

    let mut live = 0.0f64;
    let mut peak = 0.0f64;
    for (i, node) in graph.nodes.iter().enumerate() {
        // Allocate this node's output (reshape/flatten alias their input).
        let aliases = matches!(node.op, OpKind::Reshape | OpKind::Flatten);
        if !aliases {
            live += numel(&node.out_shape) as f64 * node_elem_bytes(node);
        }
        peak = peak.max(live);
        // Free tensors whose last use was this node.
        for (j, &lu) in last_use.iter().enumerate().take(i + 1) {
            if lu == i {
                let nj = &graph.nodes[j];
                let aliases_j = matches!(nj.op, OpKind::Reshape | OpKind::Flatten);
                if !aliases_j {
                    live -= numel(&nj.out_shape) as f64 * node_elem_bytes(nj);
                }
                // Guard against double-free by marking as freed.
                // (last_use[j] can equal i only once since we mutate below.)
            }
        }
        // Mark frees so they are not repeated (set to sentinel).
        for lu in last_use.iter_mut().take(i + 1) {
            if *lu == i {
                *lu = usize::MAX;
            }
        }
    }
    peak
}

/// Weight bytes of the whole model, at each node's own dtype.
pub fn weight_bytes(graph: &Graph) -> f64 {
    graph
        .nodes
        .iter()
        .map(|n| {
            let in_shape = n
                .inputs
                .first()
                .map(|&s| graph.nodes[s].out_shape.as_slice())
                .unwrap_or(&[]);
            weight_count(n.op, &n.attrs, in_shape, &n.out_shape) as f64 * node_elem_bytes(n)
        })
        .sum()
}

/// cuDNN-style workspace: a fraction of the largest single conv activation,
/// with a pool floor applied by the caller.
pub fn workspace_bytes(graph: &Graph) -> f64 {
    graph
        .nodes
        .iter()
        .filter(|n| {
            matches!(
                n.op,
                OpKind::Conv2d | OpKind::DepthwiseConv2d | OpKind::Conv2dTranspose
            )
        })
        .map(|n| numel(&n.out_shape) as f64 * node_elem_bytes(n) * 0.5)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Attrs, GraphBuilder};

    #[test]
    fn linear_chain_peak_is_two_tensors() {
        // x -> conv -> conv -> conv, all same size: peak = in + out of one op
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 8, 16, 16]);
        let c1 = b.conv2d(x, 8, 3, 1, 1);
        let c2 = b.conv2d(c1, 8, 3, 1, 1);
        b.conv2d(c2, 8, 3, 1, 1);
        let g = b.finish();
        let t = (8 * 16 * 16) as f64 * 4.0;
        assert_eq!(peak_activation_bytes(&g), 2.0 * t);
    }

    #[test]
    fn residual_keeps_skip_alive() {
        // x -> c1 -> c2 -> add(x)  : while computing c2, x must stay live.
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 8, 16, 16]);
        let c1 = b.conv2d(x, 8, 3, 1, 1);
        let c2 = b.conv2d(c1, 8, 3, 1, 1);
        b.add(OpKind::Add, Attrs::none(), &[c2, x]);
        let g = b.finish();
        let t = (8 * 16 * 16) as f64 * 4.0;
        assert_eq!(peak_activation_bytes(&g), 3.0 * t); // x + c1 + c2
    }

    #[test]
    fn peak_scales_with_batch() {
        let build = |batch| {
            let mut b = GraphBuilder::new("t", "t", batch);
            let x = b.input(vec![batch, 16, 32, 32]);
            let c = b.conv_relu(x, 16, 3, 1, 1);
            b.conv2d(c, 16, 3, 1, 1);
            b.finish()
        };
        let p1 = peak_activation_bytes(&build(1));
        let p4 = peak_activation_bytes(&build(4));
        assert!((p4 / p1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn reshape_does_not_allocate() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 8, 4, 4]);
        let f = b.add(OpKind::Flatten, Attrs::none(), &[x]);
        b.dense(f, 8);
        let g = b.finish();
        let t = (8 * 4 * 4) as f64 * 4.0;
        let out = 8.0 * 4.0;
        assert_eq!(peak_activation_bytes(&g), t + out);
    }

    #[test]
    fn workspace_tracks_largest_conv() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 3, 64, 64]);
        let c1 = b.conv2d(x, 32, 3, 1, 1); // 32*64*64 out
        b.conv2d(c1, 16, 3, 2, 1); // smaller
        let g = b.finish();
        assert_eq!(
            workspace_bytes(&g),
            (32 * 64 * 64) as f64 * 4.0 * 0.5
        );
    }
}
