//! Kernel fusion pass: groups each producer op with the chain of
//! elementwise/activation consumers that a real inference runtime (cuDNN /
//! TensorRT / XLA) would execute as one kernel.
//!
//! Rules (single-consumer chains only, mirroring conservative vertical
//! fusion):
//!   * an elementwise op whose *first* input is the immediately preceding
//!     unfused producer joins that producer's kernel;
//!   * fused ops contribute their FLOPs but not their intermediate HBM
//!     round-trip (input bytes from the producer are dropped);
//!   * reshape/flatten are zero-cost and never form kernels.

use crate::ir::{DType, Graph, NodeId, OpKind};

use super::cost::{node_elem_bytes, op_cost, OpCost};

/// A fused kernel: one launch on the device.
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    /// Node ids fused into this kernel (first = producer).
    pub nodes: Vec<NodeId>,
    /// Aggregate cost after removing internal traffic.
    pub cost: OpCost,
    /// Whether the producer runs on tensor cores.
    pub tensor_core: bool,
    /// Element dtype of the producer op — selects the math-throughput tier
    /// (fp16/bf16 double, int8 quadruple the tensor-core rate).
    pub dtype: DType,
}

/// Partition the graph into fused kernels (in topological order),
/// computing each node's cost from scratch. Callers that already hold a
/// [`crate::simulator::GraphAnalysis`] read its cached plan instead; this
/// entry point exists for one-shot callers and as the legacy
/// recompute-from-scratch path the parity property tests pin against.
pub fn fuse(graph: &Graph) -> Vec<Kernel> {
    let costs: Vec<OpCost> = graph.nodes.iter().map(|n| op_cost(graph, n)).collect();
    fuse_with_costs(graph, &costs)
}

/// Partition the graph into fused kernels using precomputed per-node costs
/// (indexed by `NodeId`) — the fusion stage of the one-pass analysis.
pub fn fuse_with_costs(graph: &Graph, costs: &[OpCost]) -> Vec<Kernel> {
    let consumers = graph.consumers();
    let mut kernel_of: Vec<Option<usize>> = vec![None; graph.nodes.len()];
    let mut kernels: Vec<Kernel> = Vec::new();

    for node in &graph.nodes {
        if node.op == OpKind::Input {
            continue; // host copy, not a kernel
        }
        let c = costs[node.id];
        if matches!(node.op, OpKind::Reshape | OpKind::Flatten) {
            continue; // metadata-only
        }

        // Try to fuse into the kernel of our first input: allowed when this
        // op is elementwise and the producer has exactly one consumer.
        let fuse_target = node.inputs.first().and_then(|&src| {
            if node.op.is_elementwise() && consumers[src].len() == 1 {
                kernel_of[src]
            } else {
                None
            }
        });

        match fuse_target {
            Some(kid) => {
                let k = &mut kernels[kid];
                k.nodes.push(node.id);
                k.cost.flops += c.flops;
                k.cost.macs += c.macs;
                k.cost.bytes_weights += c.bytes_weights;
                // The chain's intermediate stays on-chip: drop the fused
                // op's primary input traffic; its extra inputs (e.g. the
                // residual branch of an Add) still come from HBM.
                let primary = node.inputs[0];
                let primary_bytes = crate::ir::infer::numel(
                    &graph.nodes[primary].out_shape,
                ) as f64
                    * node_elem_bytes(&graph.nodes[primary]);
                k.cost.bytes_in += c.bytes_in - primary_bytes;
                // Output of the kernel is now this op's output.
                k.cost.bytes_out = c.bytes_out;
                kernel_of[node.id] = Some(kid);
            }
            None => {
                let kid = kernels.len();
                kernels.push(Kernel {
                    nodes: vec![node.id],
                    cost: c,
                    tensor_core: node.op.is_tensor_core(),
                    dtype: node.attrs.dtype,
                });
                kernel_of[node.id] = Some(kid);
            }
        }
    }
    kernels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Attrs, GraphBuilder};

    #[test]
    fn conv_relu_fuses_into_one_kernel() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 3, 16, 16]);
        b.conv_relu(x, 8, 3, 1, 1);
        let g = b.finish();
        let ks = fuse(&g);
        assert_eq!(ks.len(), 1);
        assert_eq!(ks[0].nodes.len(), 2);
        assert!(ks[0].tensor_core);
    }

    #[test]
    fn fusion_drops_intermediate_traffic() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 3, 16, 16]);
        let c = b.conv2d(x, 8, 3, 1, 1);
        b.relu(c);
        let g = b.finish();
        let fused = fuse(&g);
        let conv_cost = op_cost(&g, &g.nodes[1]);
        // Fused kernel reads conv input+weights, writes relu output — the
        // [1,8,16,16] intermediate never hits HBM.
        assert_eq!(fused[0].cost.bytes_in, conv_cost.bytes_in);
        assert_eq!(fused[0].cost.bytes_out, conv_cost.bytes_out);
        assert!(fused[0].cost.flops > conv_cost.flops);
    }

    #[test]
    fn branch_point_blocks_fusion() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 8, 8, 8]);
        let c = b.conv2d(x, 8, 3, 1, 1);
        let r = b.relu(c); // c has 2 consumers -> relu cannot fuse
        let _ = b.add(OpKind::Add, Attrs::none(), &[r, c]);
        let g = b.finish();
        let ks = fuse(&g);
        // conv | relu | add(fused into relu? add's first input is relu which
        // has 1 consumer -> fuses) => 2 kernels
        assert_eq!(ks.len(), 2);
        assert_eq!(ks[0].nodes, vec![1]);
        assert_eq!(ks[1].nodes, vec![2, 3]);
    }

    #[test]
    fn residual_add_keeps_branch_traffic() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 8, 8, 8]);
        let c1 = b.conv2d(x, 8, 3, 1, 1);
        let c2 = b.conv2d(c1, 8, 3, 1, 1);
        let s = b.add(OpKind::Add, Attrs::none(), &[c2, c1]);
        let _ = b.relu(s);
        let g = b.finish();
        let ks = fuse(&g);
        // c1 feeds c2 and the add -> 2 consumers, so c1 is its own kernel and
        // cannot absorb anything; c2+add+relu fuse.
        assert_eq!(ks.len(), 2);
        let k2 = &ks[1];
        assert_eq!(k2.nodes.len(), 3);
        // The add still reads the residual branch from HBM.
        let branch_bytes = (8 * 8 * 8) as f64 * 4.0;
        let c2_cost = op_cost(&g, &g.nodes[2]);
        assert!((k2.cost.bytes_in - (c2_cost.bytes_in + branch_bytes)).abs() < 1e-6);
    }

    #[test]
    fn kernel_count_less_than_node_count() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 3, 32, 32]);
        let mut h = x;
        for _ in 0..4 {
            h = b.conv_relu(h, 16, 3, 1, 1);
        }
        let g = b.finish();
        let ks = fuse(&g);
        assert_eq!(ks.len(), 4); // each conv+relu pair = 1 kernel
        assert_eq!(g.n_nodes(), 9);
    }
}
