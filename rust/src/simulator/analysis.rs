//! One-pass graph analysis: the analyze-once / reuse-everywhere artifact of
//! the serving and dataset hot paths.
//!
//! Before this module existed every prediction re-derived the same per-graph
//! facts many times over: the fusion pass re-ran `op_cost` per node, latency
//! and utilization each re-ran the fusion pass, featurization re-ran
//! `op_cost` per node again, and a 7-profile MIG sweep repeated the whole
//! stack once per profile. [`GraphAnalysis::of`] computes everything exactly
//! once — per-node [`OpCost`]s, the fused [`Kernel`] plan, the static
//! feature vector (paper eq. 1), graph totals (FLOPs / MACs / weight,
//! peak-liveness and workspace bytes) and the canonical WL [`Fingerprint`]
//! — from a single cost sweep whose results every later stage shares:
//!
//! * `Simulator::{latency_s,memory_mb,energy_j,measure,measure_mig}` have
//!   `*_analyzed` twins that read the cached plan; a MIG sweep analyzes once
//!   and evaluates all 7 profiles against the same kernels.
//! * `features::{encode_graph_analyzed, fill_padded_analyzed}` featurize
//!   from the cached costs instead of recomputing them per node.
//! * The coordinator computes the analysis once at submit (the fingerprint
//!   doubles as the cache key) and carries it in the job, so the executor
//!   never re-traverses the graph.
//!
//! The fingerprint algorithm lives here (rather than in `cache`) because it
//! folds the static-feature bits the analysis already has; `cache` re-exports
//! [`Fingerprint`] unchanged, and the key format is bit-identical to the one
//! the disk snapshots of `cache::persist` were written with.

use std::fmt;

use crate::ir::{Graph, OpKind};
use crate::util::rng::splitmix64;

use super::cost::{op_cost, OpCost};
use super::fusion::{self, Kernel};
use super::memory;

/// Number of static features: the paper's eq. (1) five (MACs, batch,
/// #conv, #dense, #relu) plus four per-dtype node counts (fp32/fp16/bf16/
/// int8) so the predictor sees the quantization mix.
pub const STATIC_FEATS: usize = 9;

/// The eq. (1) prefix of the static vector. Only these five fold into the
/// fingerprint (see [`fold_fingerprint`]); the dtype counts reach the key
/// through the WL signatures instead, which keeps every pre-dtype fp32
/// fingerprint bit-identical to what persisted caches and replication
/// manifests were written with.
pub const EQ1_STATIC_FEATS: usize = 5;

/// A 128-bit structural graph fingerprint.
///
/// Deterministic hash of a model graph: two submissions of the *same
/// architecture at the same batch size* map to the same key regardless of
/// how the frontend numbered or named the nodes, while any semantic
/// difference (an op kind, an attribute, a shape, an edge, the batch)
/// changes the key with overwhelming probability.
///
/// Construction: per-node Weisfeiler–Lehman signatures from
/// [`Graph::canonical_signatures`] (id/name-invariant) are folded with an
/// order-independent multiset combine (wrapping sums of keyed mixes) over
/// nodes and edges, then mixed with the eq. (1) static features so the
/// cache key covers what the predictor sees (the dtype-mix statics are
/// covered through the WL signatures, which fold each non-fp32 node's
/// dtype — see `Graph::canonical_signatures`). Only the
/// in-repo splitmix64 is used — never `std`'s randomized hasher — so keys
/// are stable across runs, processes and machines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint {
    pub hi: u64,
    pub lo: u64,
}

// Independent lane keys; arbitrary odd constants.
const K_NODE_LO: u64 = 0x9AE1_6A3B_2F90_404F;
const K_NODE_HI: u64 = 0xC2B2_AE3D_27D4_EB4F;
const K_EDGE_LO: u64 = 0x1656_67B1_9E37_79F9;
const K_EDGE_HI: u64 = 0x27D4_EB2F_1656_67C5;

impl Fingerprint {
    /// Fingerprint a graph from scratch. Cost is O(nodes + edges) plus one
    /// cost sweep for the static bits. The serving path never calls this:
    /// it reads [`GraphAnalysis::fingerprint`], which shares the analysis'
    /// cost sweep instead of running its own.
    pub fn of_graph(graph: &Graph) -> Fingerprint {
        let (statics, _flops) = statics_sweep(graph, |i| op_cost(graph, &graph.nodes[i]));
        fold_fingerprint(graph, &statics)
    }

    /// The fingerprint as one 128-bit integer (cache/shard key).
    pub fn as_u128(self) -> u128 {
        ((self.hi as u128) << 64) | self.lo as u128
    }

    /// 32-hex-digit rendering (stable; used by the TCP API and logs).
    pub fn to_hex(self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }
}

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}{:016x}", self.hi, self.lo)
    }
}

/// Static features as exact integers for hashing. Every component of
/// eq. (1) is an integral count (MACs, batch, op counts), so rounding is
/// exact and — unlike raw f64 bit patterns — the result cannot depend on
/// summation order.
pub fn static_bits(statics: &[f64; STATIC_FEATS]) -> [u64; STATIC_FEATS] {
    std::array::from_fn(|i| statics[i].max(0.0).round() as u64)
}

/// Fold the WL node/edge multisets and the static bits into a fingerprint.
/// Shared by [`Fingerprint::of_graph`] (fresh statics) and
/// [`GraphAnalysis::of`] (statics from the cached cost sweep) — the two
/// paths are bit-identical by construction.
fn fold_fingerprint(graph: &Graph, statics: &[f64; STATIC_FEATS]) -> Fingerprint {
    let sigs = graph.canonical_signatures();
    let mut lo: u64 = 0;
    let mut hi: u64 = 0;
    // Node multiset: wrapping sums are permutation-invariant.
    for &s in &sigs {
        lo = lo.wrapping_add(splitmix64(s ^ K_NODE_LO));
        hi = hi.wrapping_add(splitmix64(s ^ K_NODE_HI));
    }
    // Edge multiset over refined endpoint signatures (directed pairs).
    for node in &graph.nodes {
        for &src in &node.inputs {
            let e = splitmix64(sigs[src])
                .wrapping_mul(0x100_0000_01B3)
                .wrapping_add(splitmix64(sigs[node.id]));
            lo = lo.wrapping_add(splitmix64(e ^ K_EDGE_LO));
            hi = hi.wrapping_add(splitmix64(e ^ K_EDGE_HI));
        }
    }
    let mut t = splitmix64(graph.batch as u64 ^ 0xBA7C_4000);
    // Fold only the eq. (1) prefix: fp32 graphs must keep their pre-dtype
    // fingerprints (the dtype counts are zero-for-fp16/bf16/i8 there, but
    // folding them at all would change every existing key).
    for v in static_bits(statics).into_iter().take(EQ1_STATIC_FEATS) {
        t = splitmix64(t ^ v);
    }
    t = splitmix64(t ^ (graph.n_nodes() as u64).rotate_left(32));
    Fingerprint {
        lo: splitmix64(lo ^ t),
        hi: splitmix64(hi ^ t.rotate_left(17)),
    }
}

/// One sweep over the nodes accumulating the eq. (1) statics and total
/// FLOPs from a per-node cost source. The MAC accumulation order is the
/// node order — identical to `cost::total_macs`, so the f64 sums agree
/// bit-for-bit with the legacy scratch path.
fn statics_sweep(graph: &Graph, cost_of: impl Fn(usize) -> OpCost) -> ([f64; STATIC_FEATS], f64) {
    let mut macs = 0.0;
    let mut flops = 0.0;
    let (mut conv, mut dense, mut relu) = (0u64, 0u64, 0u64);
    let mut dtype_counts = [0u64; crate::ir::ALL_DTYPES.len()];
    for (i, node) in graph.nodes.iter().enumerate() {
        let c = cost_of(i);
        flops += c.flops;
        if node.op.counts_macs() {
            macs += c.macs;
        }
        match node.op {
            OpKind::Conv2d | OpKind::DepthwiseConv2d | OpKind::Conv2dTranspose => conv += 1,
            OpKind::Dense => dense += 1,
            OpKind::Relu => relu += 1,
            _ => {}
        }
        dtype_counts[node.attrs.dtype.index()] += 1;
    }
    let statics = [
        macs,
        graph.batch as f64,
        conv as f64,
        dense as f64,
        relu as f64,
        dtype_counts[0] as f64,
        dtype_counts[1] as f64,
        dtype_counts[2] as f64,
        dtype_counts[3] as f64,
    ];
    (statics, flops)
}

/// Stage 1 of the one-pass analysis: the cost sweep. Per-node costs, the
/// eq. (1) statics, total FLOPs and the WL fingerprint — exactly what the
/// serving cache key needs. The coordinator's submit path runs this for
/// every request; cache hits stop here, and only misses pay
/// [`CostSweep::complete`] (fusion plan + memory totals) to become a full
/// [`GraphAnalysis`] — without re-running the sweep.
pub struct CostSweep {
    costs: Vec<OpCost>,
    statics: [f64; STATIC_FEATS],
    flops: f64,
    /// Canonical structural fingerprint (the cache key substrate).
    pub fingerprint: Fingerprint,
}

impl CostSweep {
    /// Run the cost sweep: one `op_cost` pass shared by the statics and
    /// the fingerprint fold.
    pub fn of(graph: &Graph) -> CostSweep {
        let costs: Vec<OpCost> = graph.nodes.iter().map(|n| op_cost(graph, n)).collect();
        let (statics, flops) = statics_sweep(graph, |i| costs[i]);
        let fingerprint = fold_fingerprint(graph, &statics);
        CostSweep {
            costs,
            statics,
            flops,
            fingerprint,
        }
    }

    /// Upgrade to a full [`GraphAnalysis`]: fuse the kernel plan from the
    /// already-computed costs and add the memory totals and identity
    /// fields. `graph` must be the graph this sweep was computed from.
    pub fn complete(self, graph: &Graph) -> GraphAnalysis {
        debug_assert_eq!(self.costs.len(), graph.n_nodes());
        let kernels = fusion::fuse_with_costs(graph, &self.costs);
        GraphAnalysis {
            family: graph.family.clone(),
            variant: graph.variant.clone(),
            batch: graph.batch,
            n_nodes: graph.n_nodes(),
            macs: self.statics[0],
            flops: self.flops,
            weight_bytes: memory::weight_bytes(graph),
            peak_activation_bytes: memory::peak_activation_bytes(graph),
            workspace_bytes: memory::workspace_bytes(graph),
            costs: self.costs,
            kernels,
            statics: self.statics,
            fingerprint: self.fingerprint,
        }
    }
}

/// The analyze-once artifact: everything the simulator, the featurizers,
/// the MIG advisor and the serving cache need from one graph, computed in
/// a single analysis pass (one shared cost sweep; no stage recomputes
/// another's work).
///
/// The analysis owns small copies of the graph's identity fields
/// (family/variant/batch seed the simulator's deterministic noise stream)
/// so it can travel through queues without borrowing the graph.
#[derive(Debug, Clone)]
pub struct GraphAnalysis {
    /// Family tag of the analyzed graph (noise-seed identity).
    pub family: String,
    /// Variant tag of the analyzed graph (noise-seed identity).
    pub variant: String,
    /// Inference batch size.
    pub batch: usize,
    /// Node count of the analyzed graph.
    pub n_nodes: usize,
    /// Per-node isolated costs, indexed by `NodeId`.
    pub costs: Vec<OpCost>,
    /// The fused-kernel plan (what one inference actually launches).
    pub kernels: Vec<Kernel>,
    /// Raw static feature vector (paper eq. 1 order).
    pub statics: [f64; STATIC_FEATS],
    /// Total FLOPs over all nodes.
    pub flops: f64,
    /// Total MACs (TVM convention — `counts_macs` ops only).
    pub macs: f64,
    /// Model weight bytes.
    pub weight_bytes: f64,
    /// Liveness-peak activation bytes over a topological execution.
    pub peak_activation_bytes: f64,
    /// cuDNN-style workspace bytes (largest conv scratch, before the
    /// device-level pool floor).
    pub workspace_bytes: f64,
    /// Canonical structural fingerprint (the cache key substrate).
    pub fingerprint: Fingerprint,
}

impl GraphAnalysis {
    /// Analyze a graph once. Every derived quantity is bit-identical to the
    /// legacy recompute-from-scratch helpers (`cost::op_cost`,
    /// `fusion::fuse`, `memory::*`, `features::static_features`,
    /// `Fingerprint::of_graph`) — guaranteed by the parity property tests.
    pub fn of(graph: &Graph) -> GraphAnalysis {
        CostSweep::of(graph).complete(graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Attrs, GraphBuilder};
    use crate::simulator::cost::{total_flops, total_macs};

    fn sample(batch: usize, ch: usize) -> Graph {
        let mut b = GraphBuilder::new("t", "analysis-sample", batch);
        let x = b.input(vec![batch, 3, 16, 16]);
        let c = b.conv_relu(x, ch, 3, 1, 1);
        let p = b.add(OpKind::GlobalAvgPool2d, Attrs::none(), &[c]);
        let f = b.add(OpKind::Flatten, Attrs::none(), &[p]);
        b.dense(f, 10);
        b.finish()
    }

    #[test]
    fn costs_match_scratch_op_cost() {
        let g = sample(2, 8);
        let a = GraphAnalysis::of(&g);
        assert_eq!(a.costs.len(), g.n_nodes());
        for (i, node) in g.nodes.iter().enumerate() {
            assert_eq!(a.costs[i], op_cost(&g, node), "node {i}");
        }
    }

    #[test]
    fn kernels_match_scratch_fuse() {
        let g = sample(4, 16);
        let a = GraphAnalysis::of(&g);
        assert_eq!(a.kernels, fusion::fuse(&g));
    }

    #[test]
    fn totals_match_scratch_helpers() {
        let g = sample(2, 8);
        let a = GraphAnalysis::of(&g);
        assert_eq!(a.macs, total_macs(&g));
        assert_eq!(a.flops, total_flops(&g));
        assert_eq!(a.weight_bytes, memory::weight_bytes(&g));
        assert_eq!(a.peak_activation_bytes, memory::peak_activation_bytes(&g));
        assert_eq!(a.workspace_bytes, memory::workspace_bytes(&g));
    }

    #[test]
    fn fingerprint_matches_of_graph() {
        for (batch, ch) in [(1, 8), (2, 8), (4, 32)] {
            let g = sample(batch, ch);
            assert_eq!(GraphAnalysis::of(&g).fingerprint, Fingerprint::of_graph(&g));
        }
    }

    #[test]
    fn sweep_then_complete_equals_direct_analysis() {
        let g = sample(2, 16);
        let sweep = CostSweep::of(&g);
        assert_eq!(sweep.fingerprint, Fingerprint::of_graph(&g));
        let a = sweep.complete(&g);
        let direct = GraphAnalysis::of(&g);
        assert_eq!(a.costs, direct.costs);
        assert_eq!(a.kernels, direct.kernels);
        assert_eq!(a.statics, direct.statics);
        assert_eq!(a.fingerprint, direct.fingerprint);
        assert_eq!(a.peak_activation_bytes, direct.peak_activation_bytes);
    }

    #[test]
    fn identity_fields_copied() {
        let g = sample(2, 8);
        let a = GraphAnalysis::of(&g);
        assert_eq!(a.family, g.family);
        assert_eq!(a.variant, g.variant);
        assert_eq!(a.batch, g.batch);
        assert_eq!(a.n_nodes, g.n_nodes());
    }

    #[test]
    fn dtype_mix_reaches_statics_and_fingerprint() {
        use crate::ir::quantize::quantize;
        use crate::ir::DType;
        let g = sample(2, 8);
        let a32 = GraphAnalysis::of(&g);
        // all six nodes fp32
        assert_eq!(a32.statics[5], g.n_nodes() as f64);
        assert_eq!(&a32.statics[6..], &[0.0, 0.0, 0.0]);
        let q = quantize(&g, DType::F16);
        let a16 = GraphAnalysis::of(&q);
        assert_eq!(a16.statics[6], g.n_nodes() as f64);
        assert_eq!(a16.statics[5], 0.0);
        // distinct cache keys per dtype
        assert_ne!(a16.fingerprint, a32.fingerprint);
        assert_ne!(
            GraphAnalysis::of(&quantize(&g, DType::I8)).fingerprint,
            a16.fingerprint
        );
        // eq. (1) prefix is dtype-independent (same shapes, same MACs)
        assert_eq!(a16.statics[..5], a32.statics[..5]);
    }

    #[test]
    fn statics_match_scratch_features() {
        let g = sample(8, 16);
        let a = GraphAnalysis::of(&g);
        assert_eq!(a.statics, crate::features::static_features(&g));
        let bits = crate::features::static_feature_bits(&a.statics);
        assert_eq!(static_bits(&a.statics), bits);
    }
}
