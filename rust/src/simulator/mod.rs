//! The A100 device simulator — the ground-truth substrate replacing the
//! paper's physical GPU + NVML measurements (DESIGN.md §2, §6).
//!
//! Given an [`ir::Graph`] and a [`MigProfile`], [`Simulator::measure`]
//! returns the (latency ms, memory MB, energy J) triple the paper's dataset
//! records, including the paper's measurement protocol: 5 warm-up runs are
//! implicit (the model is steady-state), and the reported value is the mean
//! of 30 noisy runs with a deterministic per-(graph, profile) noise stream.
//!
//! Every entry point has an `*_analyzed` twin taking a precomputed
//! [`GraphAnalysis`] — analyze a graph once (costs, fused kernels, memory
//! totals) and evaluate any number of metrics and MIG profiles against the
//! same plan. The graph-taking methods are one-shot conveniences that
//! analyze internally.

pub mod analysis;
pub mod cost;
pub mod device;
pub mod fusion;
pub mod memory;

use crate::ir::Graph;
use crate::util::rng::{hash_bytes, Rng};

pub use analysis::{CostSweep, Fingerprint, GraphAnalysis};
pub use device::{DeviceSpec, MigProfile, ALL_PROFILES};

/// One measured data point — the paper's Y vector (§4.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    pub latency_ms: f64,
    pub memory_mb: f64,
    pub energy_j: f64,
}

/// Result of a MIG-aware measurement: `None` memory means OOM on that slice.
#[derive(Debug, Clone, Copy)]
pub enum MigResult {
    Ok(Measurement),
    OutOfMemory { required_mb: f64, capacity_mb: f64 },
}

#[derive(Debug, Clone, Default)]
pub struct Simulator {
    pub spec: DeviceSpec,
    /// Relative std-dev of per-run measurement noise (paper: mean of 30).
    pub noise_sd: f64,
    /// Number of simulated measurement runs averaged together.
    pub runs: usize,
}

impl Simulator {
    pub fn new() -> Simulator {
        Simulator {
            spec: DeviceSpec::default(),
            noise_sd: 0.02,
            runs: 30,
        }
    }

    /// Noise-free analytical latency in seconds on a profile. Analyzes the
    /// graph first; sweeping several profiles or metrics over one graph is
    /// cheaper through [`GraphAnalysis::of`] + [`Simulator::latency_s_analyzed`].
    pub fn latency_s(&self, graph: &Graph, profile: MigProfile) -> f64 {
        self.latency_s_analyzed(&GraphAnalysis::of(graph), profile)
    }

    /// Noise-free analytical latency from a precomputed analysis: reads the
    /// cached kernel plan, never re-traverses the graph.
    pub fn latency_s_analyzed(&self, a: &GraphAnalysis, profile: MigProfile) -> f64 {
        let s = &self.spec;
        let sm = profile.sm_fraction();
        let bw = profile.bw_fraction();
        let mut total = 0.0;
        for k in &a.kernels {
            // Per-kernel dtype selects the math tier: fp32 at the legacy
            // TF32/CUDA rates, fp16/bf16 at 2x, int8 at 4x.
            let peak = if k.tensor_core {
                s.tc_flops_at(k.dtype)
            } else {
                s.cuda_flops_at(k.dtype)
            } * sm;
            let cu = s.compute_util(k.cost.flops * sm.recip().min(4.0)); // smaller slice saturates sooner
            let bu = s.bw_util(k.cost.total_bytes());
            let t_compute = if k.cost.flops > 0.0 {
                k.cost.flops / (peak * cu.max(1e-3))
            } else {
                0.0
            };
            let t_mem = k.cost.total_bytes() / (s.hbm_bw * bw * bu.max(1e-3));
            total += t_compute.max(t_mem) + s.launch_s;
        }
        total
    }

    /// Average achieved utilization (power-weighting term for energy).
    fn avg_util_analyzed(&self, a: &GraphAnalysis, profile: MigProfile) -> f64 {
        let s = &self.spec;
        let sm = profile.sm_fraction();
        let (mut t_sum, mut u_sum) = (0.0, 0.0);
        for k in &a.kernels {
            let peak = if k.tensor_core {
                s.tc_flops_at(k.dtype)
            } else {
                s.cuda_flops_at(k.dtype)
            } * sm;
            let cu = s.compute_util(k.cost.flops * sm.recip().min(4.0));
            let bu = s.bw_util(k.cost.total_bytes());
            let t_compute = if k.cost.flops > 0.0 {
                k.cost.flops / (peak * cu.max(1e-3))
            } else {
                0.0
            };
            let t_mem = k.cost.total_bytes() / (s.hbm_bw * profile.bw_fraction() * bu.max(1e-3));
            let t = t_compute.max(t_mem) + s.launch_s;
            // Utilization while this kernel runs: how close to the roofline.
            let u = if t > 0.0 {
                (t_compute.max(t_mem) / t) * cu.max(bu)
            } else {
                0.0
            };
            t_sum += t;
            u_sum += u * t;
        }
        if t_sum > 0.0 {
            u_sum / t_sum
        } else {
            0.0
        }
    }

    /// Noise-free memory consumption in MB on a profile.
    ///
    /// The context term scales mildly with slice capacity — the effect the
    /// paper's Fig. 3 shows (consumption slightly increases with the MIG
    /// profile, and is always highest on 7g.40gb).
    pub fn memory_mb(&self, graph: &Graph, profile: MigProfile) -> f64 {
        self.memory_mb_analyzed(&GraphAnalysis::of(graph), profile)
    }

    /// Noise-free memory consumption from a precomputed analysis.
    pub fn memory_mb_analyzed(&self, a: &GraphAnalysis, profile: MigProfile) -> f64 {
        let s = &self.spec;
        let act = a.peak_activation_bytes / 1e6;
        let w = a.weight_bytes / 1e6;
        let ws = (a.workspace_bytes / 1e6).max(s.workspace_floor_mb)
            * profile.sm_fraction().sqrt(); // smaller slices get smaller pools
        let context = s.context_mb * (0.62 + 0.38 * profile.bw_fraction());
        context + w + s.alloc_slack * act + ws
    }

    /// Noise-free energy in joules for one inference on a profile.
    pub fn energy_j(&self, graph: &Graph, profile: MigProfile) -> f64 {
        self.energy_j_analyzed(&GraphAnalysis::of(graph), profile)
    }

    /// Noise-free energy from a precomputed analysis (latency and
    /// utilization share the same cached kernel plan).
    pub fn energy_j_analyzed(&self, a: &GraphAnalysis, profile: MigProfile) -> f64 {
        let t = self.latency_s_analyzed(a, profile);
        let u = self.avg_util_analyzed(a, profile);
        let frac = profile.sm_fraction();
        // Board power attributed to the slice: share of idle + dynamic.
        let p = self.spec.idle_w * frac + (self.spec.tdp_w - self.spec.idle_w) * frac * u;
        p * t
    }

    /// Full measurement protocol on the 7g.40gb profile (paper §4.1: the
    /// dataset is collected on the full GPU).
    pub fn measure(&self, graph: &Graph) -> Measurement {
        self.measure_on(graph, MigProfile::G7_40)
    }

    /// [`Simulator::measure`] from a precomputed analysis.
    pub fn measure_analyzed(&self, a: &GraphAnalysis) -> Measurement {
        self.measure_on_analyzed(a, MigProfile::G7_40)
    }

    /// Measurement with the paper's protocol on a given profile: mean of
    /// `runs` noisy samples, deterministic per (graph variant, profile).
    pub fn measure_on(&self, graph: &Graph, profile: MigProfile) -> Measurement {
        self.measure_on_analyzed(&GraphAnalysis::of(graph), profile)
    }

    /// [`Simulator::measure_on`] from a precomputed analysis: latency,
    /// memory and energy all read the same cached plan — one analysis
    /// serves the full measurement (and, via repeated calls, a whole MIG
    /// profile sweep).
    pub fn measure_on_analyzed(&self, a: &GraphAnalysis, profile: MigProfile) -> Measurement {
        let lat = self.latency_s_analyzed(a, profile) * 1e3;
        let mem = self.memory_mb_analyzed(a, profile);
        let en = self.energy_j_analyzed(a, profile);
        let seed = hash_bytes(
            format!("{}|{}|{}|{}", a.family, a.variant, a.batch, profile.name()).as_bytes(),
        );
        let mut rng = Rng::new(seed);
        let noisy_mean = |rng: &mut Rng, base: f64| -> f64 {
            let runs = self.runs.max(1);
            let mut acc = 0.0;
            for _ in 0..runs {
                acc += base * (1.0 + self.noise_sd * rng.gaussian());
            }
            acc / runs as f64
        };
        Measurement {
            latency_ms: noisy_mean(&mut rng, lat),
            // Memory is allocator-deterministic: a single noisy sample
            // rounded to MB, like nvidia-smi reporting.
            memory_mb: (mem * (1.0 + 0.005 * rng.gaussian())).round(),
            energy_j: noisy_mean(&mut rng, en),
        }
    }

    /// MIG-aware measurement that reports OOM when the graph cannot fit.
    pub fn measure_mig(&self, graph: &Graph, profile: MigProfile) -> MigResult {
        self.measure_mig_analyzed(&GraphAnalysis::of(graph), profile)
    }

    /// [`Simulator::measure_mig`] from a precomputed analysis.
    pub fn measure_mig_analyzed(&self, a: &GraphAnalysis, profile: MigProfile) -> MigResult {
        let mem = self.memory_mb_analyzed(a, profile);
        if mem > profile.capacity_mb() {
            return MigResult::OutOfMemory {
                required_mb: mem,
                capacity_mb: profile.capacity_mb(),
            };
        }
        MigResult::Ok(self.measure_on_analyzed(a, profile))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    fn convnet(batch: usize, ch: usize, layers: usize) -> Graph {
        let mut b = GraphBuilder::new("t", &format!("convnet-c{ch}-l{layers}-b{batch}"), batch);
        let x = b.input(vec![batch, 3, 64, 64]);
        let mut h = b.conv_relu(x, ch, 3, 1, 1);
        for _ in 1..layers {
            h = b.conv_relu(h, ch, 3, 1, 1);
        }
        b.finish()
    }

    #[test]
    fn measurement_is_deterministic() {
        let sim = Simulator::new();
        let g = convnet(4, 32, 4);
        let a = sim.measure(&g);
        let b = sim.measure(&g);
        assert_eq!(a, b);
    }

    #[test]
    fn more_work_more_latency_and_energy() {
        let sim = Simulator::new();
        let small = convnet(1, 16, 2);
        let big = convnet(1, 64, 8);
        assert!(sim.latency_s(&big, MigProfile::G7_40) > sim.latency_s(&small, MigProfile::G7_40));
        assert!(sim.energy_j(&big, MigProfile::G7_40) > sim.energy_j(&small, MigProfile::G7_40));
    }

    #[test]
    fn bigger_batch_more_memory() {
        let sim = Simulator::new();
        assert!(
            sim.memory_mb(&convnet(16, 32, 4), MigProfile::G7_40)
                > sim.memory_mb(&convnet(1, 32, 4), MigProfile::G7_40)
        );
    }

    #[test]
    fn smaller_slice_is_slower() {
        let sim = Simulator::new();
        let g = convnet(8, 64, 6);
        let full = sim.latency_s(&g, MigProfile::G7_40);
        let slice = sim.latency_s(&g, MigProfile::G1_5);
        assert!(slice > full * 1.5, "slice {slice} vs full {full}");
    }

    #[test]
    fn fig3_memory_increases_with_profile_capacity() {
        // The paper's Fig. 3 effect: same model, slightly more memory on
        // bigger profiles; highest on 7g.40gb.
        let sim = Simulator::new();
        let g = convnet(16, 32, 4);
        let mems: Vec<f64> = ALL_PROFILES
            .iter()
            .map(|&p| sim.memory_mb(&g, p))
            .collect();
        assert!(mems.windows(2).all(|w| w[0] < w[1]), "{mems:?}");
        let spread = (mems[3] - mems[0]) / mems[3];
        assert!(spread < 0.45, "profile effect too large: {mems:?}");
    }

    #[test]
    fn oom_on_small_slice() {
        let sim = Simulator::new();
        // ~2.4 GB per activation tensor: far beyond the 5 GB slice.
        let mut b = GraphBuilder::new("t", "huge-b256", 256);
        let x = b.input(vec![256, 3, 96, 96]);
        let c1 = b.conv_relu(x, 256, 3, 1, 1);
        b.conv_relu(c1, 256, 3, 1, 1);
        let g = b.finish();
        match sim.measure_mig(&g, MigProfile::G1_5) {
            MigResult::OutOfMemory { required_mb, capacity_mb } => {
                assert!(required_mb > capacity_mb);
            }
            MigResult::Ok(m) => panic!("expected OOM, got {m:?}"),
        }
    }

    #[test]
    fn latency_in_plausible_range() {
        // A 6-layer 64ch convnet at batch 8 on the full GPU: O(0.1–10 ms).
        let sim = Simulator::new();
        let ms = sim.latency_s(&convnet(8, 64, 6), MigProfile::G7_40) * 1e3;
        assert!(ms > 0.05 && ms < 50.0, "latency {ms} ms");
    }

    #[test]
    fn analyzed_entry_points_match_graph_entry_points() {
        // One analysis, all metrics, every profile: bit-identical to the
        // per-call wrappers (which analyze internally).
        let sim = Simulator::new();
        let g = convnet(4, 32, 4);
        let a = GraphAnalysis::of(&g);
        for &p in &ALL_PROFILES {
            assert_eq!(sim.latency_s_analyzed(&a, p), sim.latency_s(&g, p));
            assert_eq!(sim.memory_mb_analyzed(&a, p), sim.memory_mb(&g, p));
            assert_eq!(sim.energy_j_analyzed(&a, p), sim.energy_j(&g, p));
            assert_eq!(sim.measure_on_analyzed(&a, p), sim.measure_on(&g, p));
        }
        assert_eq!(sim.measure_analyzed(&a), sim.measure(&g));
    }

    #[test]
    fn quantized_variants_predict_lower_latency_and_memory() {
        use crate::ir::quantize::quantize;
        use crate::ir::DType;
        let sim = Simulator::new();
        let g = convnet(8, 64, 6);
        let f32_lat = sim.latency_s(&g, MigProfile::G7_40);
        let f32_mem = sim.memory_mb(&g, MigProfile::G7_40);
        for dt in [DType::F16, DType::BF16, DType::I8] {
            let q = quantize(&g, dt);
            let lat = sim.latency_s(&q, MigProfile::G7_40);
            let mem = sim.memory_mb(&q, MigProfile::G7_40);
            assert!(lat < f32_lat, "{dt}: {lat} !< {f32_lat}");
            assert!(mem < f32_mem, "{dt}: {mem} !< {f32_mem}");
        }
        // int8 beats fp16 (narrower bytes, faster math)
        assert!(
            sim.latency_s(&quantize(&g, DType::I8), MigProfile::G7_40)
                < sim.latency_s(&quantize(&g, DType::F16), MigProfile::G7_40)
        );
        // explicit fp32 is bit-identical to the default path
        let f32_explicit = quantize(&g, DType::F32);
        assert_eq!(sim.measure(&f32_explicit), sim.measure(&g));
    }

    #[test]
    fn noise_is_small_relative_to_signal() {
        let sim = Simulator::new();
        let g = convnet(4, 32, 4);
        let m = sim.measure(&g);
        let clean = sim.latency_s(&g, MigProfile::G7_40) * 1e3;
        assert!((m.latency_ms - clean).abs() / clean < 0.05);
    }
}
