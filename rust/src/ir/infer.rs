//! Shape inference: output shape of an op from its attributes and input
//! shapes. Shapes are NCHW for 4-D tensors, `[N, tokens, dim]` for 3-D
//! (transformers), `[N, features]` for 2-D.

use super::op::{Attrs, OpKind};

pub type Shape = Vec<usize>;

/// Infer the output shape, or an error string describing the mismatch.
pub fn infer_shape(op: OpKind, attrs: &Attrs, inputs: &[&Shape]) -> Result<Shape, String> {
    let need = |n: usize| -> Result<(), String> {
        if inputs.len() != n {
            Err(format!("{op} expects {n} input(s), got {}", inputs.len()))
        } else {
            Ok(())
        }
    };
    match op {
        OpKind::Input => Err("input nodes carry their own shape".into()),

        OpKind::Conv2d | OpKind::DepthwiseConv2d | OpKind::Conv2dTranspose => {
            need(1)?;
            let s = inputs[0];
            if s.len() != 4 {
                return Err(format!("{op} needs NCHW input, got {s:?}"));
            }
            let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
            let (kh, kw) = attrs.kernel.ok_or("conv needs kernel")?;
            let (sh, sw) = attrs.strides.unwrap_or((1, 1));
            let p = attrs.padding;
            let out_c = match op {
                OpKind::DepthwiseConv2d => c,
                _ => attrs.units.ok_or("conv needs units (out channels)")?,
            };
            if op == OpKind::DepthwiseConv2d && attrs.groups != c {
                return Err(format!(
                    "depthwise conv groups ({}) must equal C_in ({c})",
                    attrs.groups
                ));
            }
            if op != OpKind::DepthwiseConv2d && c % attrs.groups.max(1) != 0 {
                return Err(format!("C_in {c} not divisible by groups {}", attrs.groups));
            }
            let (oh, ow) = if op == OpKind::Conv2dTranspose {
                (h * sh, w * sw) // common upsampling configuration
            } else {
                if h + 2 * p < kh || w + 2 * p < kw {
                    return Err(format!("kernel {kh}x{kw} larger than padded input {h}x{w}"));
                }
                ((h + 2 * p - kh) / sh + 1, (w + 2 * p - kw) / sw + 1)
            };
            if oh == 0 || ow == 0 {
                return Err(format!("{op} output collapsed to zero: {oh}x{ow}"));
            }
            Ok(vec![n, out_c, oh, ow])
        }

        OpKind::Dense => {
            need(1)?;
            let s = inputs[0];
            let units = attrs.units.ok_or("dense needs units")?;
            match s.len() {
                2 => Ok(vec![s[0], units]),
                3 => Ok(vec![s[0], s[1], units]), // token-wise linear
                _ => Err(format!("dense needs 2-D or 3-D input, got {s:?}")),
            }
        }

        OpKind::BatchMatmul => {
            need(2)?;
            let (a, b) = (inputs[0], inputs[1]);
            if a.len() != 3 || b.len() != 3 {
                return Err(format!("batch_matmul needs 3-D inputs, got {a:?} x {b:?}"));
            }
            if a[0] != b[0] || a[2] != b[1] {
                return Err(format!("batch_matmul shape mismatch {a:?} x {b:?}"));
            }
            Ok(vec![a[0], a[1], b[2]])
        }

        OpKind::Relu
        | OpKind::Gelu
        | OpKind::Sigmoid
        | OpKind::HardSwish
        | OpKind::Softmax
        | OpKind::BatchNorm
        | OpKind::LayerNorm => {
            need(1)?;
            Ok(inputs[0].clone())
        }

        OpKind::Add | OpKind::Multiply => {
            need(2)?;
            if inputs[0] != inputs[1] {
                return Err(format!(
                    "elementwise shape mismatch {:?} vs {:?}",
                    inputs[0], inputs[1]
                ));
            }
            Ok(inputs[0].clone())
        }

        OpKind::Concat => {
            if inputs.is_empty() {
                return Err("concat needs at least one input".into());
            }
            let axis = attrs.axis.unwrap_or(1) as usize;
            let first = inputs[0];
            if axis >= first.len() {
                return Err(format!("concat axis {axis} out of rank {}", first.len()));
            }
            let mut out = first.clone();
            for s in &inputs[1..] {
                if s.len() != first.len() {
                    return Err("concat rank mismatch".into());
                }
                for (d, (&a, &b)) in first.iter().zip(s.iter()).enumerate() {
                    if d != axis && a != b {
                        return Err(format!(
                            "concat non-axis dim mismatch at {d}: {a} vs {b}"
                        ));
                    }
                }
                out[axis] += s[axis];
            }
            out[axis] = inputs.iter().map(|s| s[axis]).sum();
            Ok(out)
        }

        OpKind::MaxPool2d | OpKind::AvgPool2d => {
            need(1)?;
            let s = inputs[0];
            if s.len() != 4 {
                return Err(format!("{op} needs NCHW input, got {s:?}"));
            }
            let (kh, kw) = attrs.kernel.ok_or("pool needs kernel")?;
            let (sh, sw) = attrs.strides.unwrap_or((kh, kw));
            let p = attrs.padding;
            let oh = (s[2] + 2 * p - kh) / sh + 1;
            let ow = (s[3] + 2 * p - kw) / sw + 1;
            if oh == 0 || ow == 0 {
                return Err("pool output collapsed to zero".into());
            }
            Ok(vec![s[0], s[1], oh, ow])
        }

        OpKind::GlobalAvgPool2d => {
            need(1)?;
            let s = inputs[0];
            if s.len() != 4 {
                return Err(format!("global pool needs NCHW input, got {s:?}"));
            }
            Ok(vec![s[0], s[1], 1, 1])
        }

        OpKind::Flatten => {
            need(1)?;
            let s = inputs[0];
            Ok(vec![s[0], s[1..].iter().product::<usize>().max(1)])
        }

        OpKind::Reshape | OpKind::Transpose | OpKind::StridedSlice => {
            // Target shape supplied out-of-band by the builder (these ops
            // keep or reduce element count; validation happens in the graph).
            need(1)?;
            Ok(inputs[0].clone())
        }

        OpKind::Mean => {
            need(1)?;
            let s = inputs[0];
            let axis = attrs.axis.unwrap_or(1) as usize;
            if axis >= s.len() {
                return Err(format!("mean axis {axis} out of rank {}", s.len()));
            }
            let mut out = s.clone();
            out.remove(axis);
            if out.is_empty() {
                out.push(1);
            }
            Ok(out)
        }
    }
}

/// Element count of a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(if shape.is_empty() { 0 } else { 1 })
}

/// Trainable weight parameter count of an op (for model-size accounting).
pub fn weight_count(op: OpKind, attrs: &Attrs, in_shape: &[usize], out_shape: &[usize]) -> usize {
    match op {
        OpKind::Conv2d | OpKind::Conv2dTranspose => {
            let (kh, kw) = attrs.kernel.unwrap_or((1, 1));
            let c_in = in_shape.get(1).copied().unwrap_or(1);
            let c_out = out_shape.get(1).copied().unwrap_or(1);
            let g = attrs.groups.max(1);
            c_out * (c_in / g) * kh * kw + c_out
        }
        OpKind::DepthwiseConv2d => {
            let (kh, kw) = attrs.kernel.unwrap_or((1, 1));
            let c = in_shape.get(1).copied().unwrap_or(1);
            c * kh * kw + c
        }
        OpKind::Dense => {
            let d_in = *in_shape.last().unwrap_or(&1);
            let d_out = *out_shape.last().unwrap_or(&1);
            d_in * d_out + d_out
        }
        OpKind::BatchNorm => 2 * in_shape.get(1).copied().unwrap_or(1),
        OpKind::LayerNorm => 2 * in_shape.last().copied().unwrap_or(1),
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape() {
        let s = vec![1, 3, 224, 224];
        let out =
            infer_shape(OpKind::Conv2d, &Attrs::conv(64, 7, 2, 3, 1), &[&s]).unwrap();
        assert_eq!(out, vec![1, 64, 112, 112]);
    }

    #[test]
    fn depthwise_preserves_channels() {
        let s = vec![2, 32, 56, 56];
        let mut a = Attrs::conv(0, 3, 1, 1, 32);
        a.units = None;
        let out = infer_shape(OpKind::DepthwiseConv2d, &a, &[&s]).unwrap();
        assert_eq!(out, vec![2, 32, 56, 56]);
    }

    #[test]
    fn depthwise_group_mismatch_rejected() {
        let s = vec![2, 32, 56, 56];
        let mut a = Attrs::conv(0, 3, 1, 1, 16);
        a.units = None;
        assert!(infer_shape(OpKind::DepthwiseConv2d, &a, &[&s]).is_err());
    }

    #[test]
    fn dense_2d_and_3d() {
        assert_eq!(
            infer_shape(OpKind::Dense, &Attrs::dense(10), &[&vec![4, 512]]).unwrap(),
            vec![4, 10]
        );
        assert_eq!(
            infer_shape(OpKind::Dense, &Attrs::dense(768), &[&vec![4, 197, 384]])
                .unwrap(),
            vec![4, 197, 768]
        );
    }

    #[test]
    fn batch_matmul_checks_dims() {
        let a = vec![8, 197, 64];
        let b = vec![8, 64, 197];
        assert_eq!(
            infer_shape(OpKind::BatchMatmul, &Attrs::none(), &[&a, &b]).unwrap(),
            vec![8, 197, 197]
        );
        let bad = vec![8, 32, 197];
        assert!(infer_shape(OpKind::BatchMatmul, &Attrs::none(), &[&a, &bad]).is_err());
    }

    #[test]
    fn concat_sums_axis() {
        let a = vec![1, 64, 28, 28];
        let b = vec![1, 32, 28, 28];
        let out =
            infer_shape(OpKind::Concat, &Attrs::with_axis(1), &[&a, &b]).unwrap();
        assert_eq!(out, vec![1, 96, 28, 28]);
    }

    #[test]
    fn concat_rejects_mismatch() {
        let a = vec![1, 64, 28, 28];
        let b = vec![1, 32, 14, 14];
        assert!(infer_shape(OpKind::Concat, &Attrs::with_axis(1), &[&a, &b]).is_err());
    }

    #[test]
    fn pool_defaults_stride_to_kernel() {
        let s = vec![1, 64, 56, 56];
        let out = infer_shape(
            OpKind::MaxPool2d,
            &Attrs {
                kernel: Some((2, 2)),
                ..Attrs::none()
            },
            &[&s],
        )
        .unwrap();
        assert_eq!(out, vec![1, 64, 28, 28]);
    }

    #[test]
    fn global_pool_and_flatten() {
        let s = vec![2, 1280, 7, 7];
        let g = infer_shape(OpKind::GlobalAvgPool2d, &Attrs::none(), &[&s]).unwrap();
        assert_eq!(g, vec![2, 1280, 1, 1]);
        let f = infer_shape(OpKind::Flatten, &Attrs::none(), &[&g]).unwrap();
        assert_eq!(f, vec![2, 1280]);
    }

    #[test]
    fn mean_removes_axis() {
        let s = vec![4, 197, 384];
        let out = infer_shape(OpKind::Mean, &Attrs::with_axis(1), &[&s]).unwrap();
        assert_eq!(out, vec![4, 384]);
    }

    #[test]
    fn elementwise_requires_same_shape() {
        let a = vec![1, 64, 28, 28];
        assert!(infer_shape(OpKind::Add, &Attrs::none(), &[&a, &a]).is_ok());
        let b = vec![1, 32, 28, 28];
        assert!(infer_shape(OpKind::Add, &Attrs::none(), &[&a, &b]).is_err());
    }

    #[test]
    fn weight_counts() {
        // conv 3->64, 7x7: 64*3*49 + 64
        assert_eq!(
            weight_count(
                OpKind::Conv2d,
                &Attrs::conv(64, 7, 2, 3, 1),
                &[1, 3, 224, 224],
                &[1, 64, 112, 112]
            ),
            64 * 3 * 49 + 64
        );
        assert_eq!(
            weight_count(OpKind::Dense, &Attrs::dense(10), &[1, 512], &[1, 10]),
            512 * 10 + 10
        );
        assert_eq!(weight_count(OpKind::Relu, &Attrs::none(), &[1, 8], &[1, 8]), 0);
    }
}
