//! Shape inference: output shape of an op from its attributes and input
//! shapes. Shapes are NCHW for 4-D tensors, `[N, tokens, dim]` for 3-D
//! (transformers), `[N, features]` for 2-D.

use super::op::{Attrs, OpKind};

pub type Shape = Vec<usize>;

/// Hard cap on elements per tensor (2^34 ≈ 17 G elements — 64 GiB at fp32,
/// beyond any single-GPU budget we model). Hostile dims that overflow a
/// `usize` product, or merely exceed this cap, are rejected by
/// [`checked_numel`] / `Graph::validate` instead of wrapping in release
/// builds and producing bogus tiny costs.
pub const MAX_TENSOR_ELEMS: usize = 1 << 34;

/// Overflow-checked element count of a shape, capped at
/// [`MAX_TENSOR_ELEMS`]. Empty shapes count as 1 (scalar), matching
/// [`numel`].
pub fn checked_numel(shape: &[usize]) -> Result<usize, String> {
    let mut n: usize = 1;
    for &d in shape {
        n = n
            .checked_mul(d)
            .ok_or_else(|| format!("tensor shape {shape:?} overflows element count"))?;
    }
    if n > MAX_TENSOR_ELEMS {
        return Err(format!(
            "tensor shape {shape:?} has {n} elements, beyond the {MAX_TENSOR_ELEMS} cap"
        ));
    }
    Ok(n.max(1))
}

/// Normalize an axis with ONNX semantics: negative axes count from the
/// back (`axis += rank`). Out-of-range axes (after normalization) error.
pub fn normalize_axis(axis: i64, rank: usize) -> Result<usize, String> {
    let r = rank as i64;
    let a = if axis < 0 { axis + r } else { axis };
    if a < 0 || a >= r {
        return Err(format!("axis {axis} out of rank {rank}"));
    }
    Ok(a as usize)
}

/// Infer the output shape, or an error string describing the mismatch.
pub fn infer_shape(op: OpKind, attrs: &Attrs, inputs: &[&Shape]) -> Result<Shape, String> {
    let need = |n: usize| -> Result<(), String> {
        if inputs.len() != n {
            Err(format!("{op} expects {n} input(s), got {}", inputs.len()))
        } else {
            Ok(())
        }
    };
    match op {
        OpKind::Input => Err("input nodes carry their own shape".into()),

        OpKind::Conv2d | OpKind::DepthwiseConv2d | OpKind::Conv2dTranspose => {
            need(1)?;
            let s = inputs[0];
            if s.len() != 4 {
                return Err(format!("{op} needs NCHW input, got {s:?}"));
            }
            let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
            let (kh, kw) = attrs.kernel.ok_or("conv needs kernel")?;
            let (sh, sw) = attrs.strides.unwrap_or((1, 1));
            if sh == 0 || sw == 0 {
                return Err(format!("{op} stride must be nonzero"));
            }
            let p = attrs.padding;
            let out_c = match op {
                OpKind::DepthwiseConv2d => c,
                _ => attrs.units.ok_or("conv needs units (out channels)")?,
            };
            if op == OpKind::DepthwiseConv2d && attrs.groups != c {
                return Err(format!(
                    "depthwise conv groups ({}) must equal C_in ({c})",
                    attrs.groups
                ));
            }
            if op != OpKind::DepthwiseConv2d && c % attrs.groups.max(1) != 0 {
                return Err(format!("C_in {c} not divisible by groups {}", attrs.groups));
            }
            let (oh, ow) = if op == OpKind::Conv2dTranspose {
                // common upsampling configuration
                let oh = h
                    .checked_mul(sh)
                    .ok_or_else(|| format!("{op} output height overflows"))?;
                let ow = w
                    .checked_mul(sw)
                    .ok_or_else(|| format!("{op} output width overflows"))?;
                (oh, ow)
            } else {
                let ph = padded_extent(h, p)
                    .ok_or_else(|| format!("{op} padded height overflows"))?;
                let pw = padded_extent(w, p)
                    .ok_or_else(|| format!("{op} padded width overflows"))?;
                if ph < kh || pw < kw {
                    return Err(format!("kernel {kh}x{kw} larger than padded input {h}x{w}"));
                }
                ((ph - kh) / sh + 1, (pw - kw) / sw + 1)
            };
            if oh == 0 || ow == 0 {
                return Err(format!("{op} output collapsed to zero: {oh}x{ow}"));
            }
            Ok(vec![n, out_c, oh, ow])
        }

        OpKind::Dense => {
            need(1)?;
            let s = inputs[0];
            let units = attrs.units.ok_or("dense needs units")?;
            match s.len() {
                2 => Ok(vec![s[0], units]),
                3 => Ok(vec![s[0], s[1], units]), // token-wise linear
                _ => Err(format!("dense needs 2-D or 3-D input, got {s:?}")),
            }
        }

        OpKind::BatchMatmul => {
            need(2)?;
            let (a, b) = (inputs[0], inputs[1]);
            if a.len() != 3 || b.len() != 3 {
                return Err(format!("batch_matmul needs 3-D inputs, got {a:?} x {b:?}"));
            }
            if a[0] != b[0] || a[2] != b[1] {
                return Err(format!("batch_matmul shape mismatch {a:?} x {b:?}"));
            }
            Ok(vec![a[0], a[1], b[2]])
        }

        OpKind::Relu
        | OpKind::Gelu
        | OpKind::Sigmoid
        | OpKind::HardSwish
        | OpKind::Softmax
        | OpKind::BatchNorm
        | OpKind::LayerNorm => {
            need(1)?;
            Ok(inputs[0].clone())
        }

        OpKind::Add | OpKind::Multiply => {
            need(2)?;
            if inputs[0] != inputs[1] {
                return Err(format!(
                    "elementwise shape mismatch {:?} vs {:?}",
                    inputs[0], inputs[1]
                ));
            }
            Ok(inputs[0].clone())
        }

        OpKind::Concat => {
            if inputs.is_empty() {
                return Err("concat needs at least one input".into());
            }
            let first = inputs[0];
            let axis = normalize_axis(attrs.axis.unwrap_or(1), first.len())?;
            let mut out = first.clone();
            for s in &inputs[1..] {
                if s.len() != first.len() {
                    return Err("concat rank mismatch".into());
                }
                for (d, (&a, &b)) in first.iter().zip(s.iter()).enumerate() {
                    if d != axis && a != b {
                        return Err(format!(
                            "concat non-axis dim mismatch at {d}: {a} vs {b}"
                        ));
                    }
                }
            }
            out[axis] = inputs
                .iter()
                .try_fold(0usize, |acc, s| acc.checked_add(s[axis]))
                .ok_or("concat axis length overflows")?;
            Ok(out)
        }

        OpKind::MaxPool2d | OpKind::AvgPool2d => {
            need(1)?;
            let s = inputs[0];
            if s.len() != 4 {
                return Err(format!("{op} needs NCHW input, got {s:?}"));
            }
            let (kh, kw) = attrs.kernel.ok_or("pool needs kernel")?;
            let (sh, sw) = attrs.strides.unwrap_or((kh, kw));
            if sh == 0 || sw == 0 {
                return Err(format!("{op} stride must be nonzero"));
            }
            let p = attrs.padding;
            let ph = padded_extent(s[2], p).ok_or_else(|| format!("{op} padded height overflows"))?;
            let pw = padded_extent(s[3], p).ok_or_else(|| format!("{op} padded width overflows"))?;
            if ph < kh || pw < kw {
                return Err(format!("kernel {kh}x{kw} larger than padded input"));
            }
            let oh = (ph - kh) / sh + 1;
            let ow = (pw - kw) / sw + 1;
            if oh == 0 || ow == 0 {
                return Err("pool output collapsed to zero".into());
            }
            Ok(vec![s[0], s[1], oh, ow])
        }

        OpKind::GlobalAvgPool2d => {
            need(1)?;
            let s = inputs[0];
            if s.len() != 4 {
                return Err(format!("global pool needs NCHW input, got {s:?}"));
            }
            Ok(vec![s[0], s[1], 1, 1])
        }

        OpKind::Flatten => {
            need(1)?;
            let s = inputs[0];
            let rest = s[1..]
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| format!("flatten of {s:?} overflows"))?;
            Ok(vec![s[0], rest.max(1)])
        }

        OpKind::Reshape | OpKind::Transpose | OpKind::StridedSlice => {
            // Target shape supplied out-of-band by the builder (these ops
            // keep or reduce element count; validation happens in the graph).
            need(1)?;
            Ok(inputs[0].clone())
        }

        OpKind::Mean => {
            need(1)?;
            let s = inputs[0];
            let axis = normalize_axis(attrs.axis.unwrap_or(1), s.len())?;
            let mut out = s.clone();
            out.remove(axis);
            if out.is_empty() {
                out.push(1);
            }
            Ok(out)
        }
    }
}

/// `extent + 2 * padding`, or `None` on overflow.
fn padded_extent(extent: usize, padding: usize) -> Option<usize> {
    padding.checked_mul(2).and_then(|p2| extent.checked_add(p2))
}

/// Element count of a shape. Saturates instead of wrapping on overflow;
/// graphs that pass [`crate::ir::Graph::validate`] (which runs
/// [`checked_numel`] per node) never reach saturation.
pub fn numel(shape: &[usize]) -> usize {
    let n = shape
        .iter()
        .fold(1usize, |acc, &d| acc.saturating_mul(d));
    n.max(if shape.is_empty() { 0 } else { 1 })
}

/// Trainable weight parameter count of an op (for model-size accounting).
/// Saturates on overflow; [`checked_weight_count`] is the erroring variant
/// used by graph validation.
pub fn weight_count(op: OpKind, attrs: &Attrs, in_shape: &[usize], out_shape: &[usize]) -> usize {
    checked_weight_count(op, attrs, in_shape, out_shape).unwrap_or(usize::MAX)
}

/// Overflow-checked trainable weight parameter count.
pub fn checked_weight_count(
    op: OpKind,
    attrs: &Attrs,
    in_shape: &[usize],
    out_shape: &[usize],
) -> Result<usize, String> {
    let overflow = || format!("{op} weight count overflows (in {in_shape:?}, out {out_shape:?})");
    let prod = |dims: &[usize]| -> Result<usize, String> {
        dims.iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(overflow)
    };
    match op {
        OpKind::Conv2d | OpKind::Conv2dTranspose => {
            let (kh, kw) = attrs.kernel.unwrap_or((1, 1));
            let c_in = in_shape.get(1).copied().unwrap_or(1);
            let c_out = out_shape.get(1).copied().unwrap_or(1);
            let g = attrs.groups.max(1);
            prod(&[c_out, c_in / g, kh, kw])?
                .checked_add(c_out)
                .ok_or_else(overflow)
        }
        OpKind::DepthwiseConv2d => {
            let (kh, kw) = attrs.kernel.unwrap_or((1, 1));
            let c = in_shape.get(1).copied().unwrap_or(1);
            prod(&[c, kh, kw])?.checked_add(c).ok_or_else(overflow)
        }
        OpKind::Dense => {
            let d_in = *in_shape.last().unwrap_or(&1);
            let d_out = *out_shape.last().unwrap_or(&1);
            prod(&[d_in, d_out])?.checked_add(d_out).ok_or_else(overflow)
        }
        OpKind::BatchNorm => in_shape
            .get(1)
            .copied()
            .unwrap_or(1)
            .checked_mul(2)
            .ok_or_else(overflow),
        OpKind::LayerNorm => in_shape
            .last()
            .copied()
            .unwrap_or(1)
            .checked_mul(2)
            .ok_or_else(overflow),
        _ => Ok(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape() {
        let s = vec![1, 3, 224, 224];
        let out =
            infer_shape(OpKind::Conv2d, &Attrs::conv(64, 7, 2, 3, 1), &[&s]).unwrap();
        assert_eq!(out, vec![1, 64, 112, 112]);
    }

    #[test]
    fn depthwise_preserves_channels() {
        let s = vec![2, 32, 56, 56];
        let mut a = Attrs::conv(0, 3, 1, 1, 32);
        a.units = None;
        let out = infer_shape(OpKind::DepthwiseConv2d, &a, &[&s]).unwrap();
        assert_eq!(out, vec![2, 32, 56, 56]);
    }

    #[test]
    fn depthwise_group_mismatch_rejected() {
        let s = vec![2, 32, 56, 56];
        let mut a = Attrs::conv(0, 3, 1, 1, 16);
        a.units = None;
        assert!(infer_shape(OpKind::DepthwiseConv2d, &a, &[&s]).is_err());
    }

    #[test]
    fn dense_2d_and_3d() {
        assert_eq!(
            infer_shape(OpKind::Dense, &Attrs::dense(10), &[&vec![4, 512]]).unwrap(),
            vec![4, 10]
        );
        assert_eq!(
            infer_shape(OpKind::Dense, &Attrs::dense(768), &[&vec![4, 197, 384]])
                .unwrap(),
            vec![4, 197, 768]
        );
    }

    #[test]
    fn batch_matmul_checks_dims() {
        let a = vec![8, 197, 64];
        let b = vec![8, 64, 197];
        assert_eq!(
            infer_shape(OpKind::BatchMatmul, &Attrs::none(), &[&a, &b]).unwrap(),
            vec![8, 197, 197]
        );
        let bad = vec![8, 32, 197];
        assert!(infer_shape(OpKind::BatchMatmul, &Attrs::none(), &[&a, &bad]).is_err());
    }

    #[test]
    fn concat_sums_axis() {
        let a = vec![1, 64, 28, 28];
        let b = vec![1, 32, 28, 28];
        let out =
            infer_shape(OpKind::Concat, &Attrs::with_axis(1), &[&a, &b]).unwrap();
        assert_eq!(out, vec![1, 96, 28, 28]);
    }

    #[test]
    fn concat_rejects_mismatch() {
        let a = vec![1, 64, 28, 28];
        let b = vec![1, 32, 14, 14];
        assert!(infer_shape(OpKind::Concat, &Attrs::with_axis(1), &[&a, &b]).is_err());
    }

    #[test]
    fn pool_defaults_stride_to_kernel() {
        let s = vec![1, 64, 56, 56];
        let out = infer_shape(
            OpKind::MaxPool2d,
            &Attrs {
                kernel: Some((2, 2)),
                ..Attrs::none()
            },
            &[&s],
        )
        .unwrap();
        assert_eq!(out, vec![1, 64, 28, 28]);
    }

    #[test]
    fn global_pool_and_flatten() {
        let s = vec![2, 1280, 7, 7];
        let g = infer_shape(OpKind::GlobalAvgPool2d, &Attrs::none(), &[&s]).unwrap();
        assert_eq!(g, vec![2, 1280, 1, 1]);
        let f = infer_shape(OpKind::Flatten, &Attrs::none(), &[&g]).unwrap();
        assert_eq!(f, vec![2, 1280]);
    }

    #[test]
    fn mean_removes_axis() {
        let s = vec![4, 197, 384];
        let out = infer_shape(OpKind::Mean, &Attrs::with_axis(1), &[&s]).unwrap();
        assert_eq!(out, vec![4, 384]);
    }

    #[test]
    fn elementwise_requires_same_shape() {
        let a = vec![1, 64, 28, 28];
        assert!(infer_shape(OpKind::Add, &Attrs::none(), &[&a, &a]).is_ok());
        let b = vec![1, 32, 28, 28];
        assert!(infer_shape(OpKind::Add, &Attrs::none(), &[&a, &b]).is_err());
    }

    #[test]
    fn negative_axes_normalize_like_onnx() {
        let s = vec![4, 197, 384];
        // mean over axis -2 == axis 1
        let out = infer_shape(OpKind::Mean, &Attrs::with_axis(-2), &[&s]).unwrap();
        assert_eq!(out, vec![4, 384]);
        let a = vec![1, 64, 28, 28];
        let b = vec![1, 32, 28, 28];
        // concat over axis -3 == axis 1 on a 4-D tensor
        let out = infer_shape(OpKind::Concat, &Attrs::with_axis(-3), &[&a, &b]).unwrap();
        assert_eq!(out, vec![1, 96, 28, 28]);
        // still-out-of-range axes error instead of reinterpreting
        assert!(infer_shape(OpKind::Mean, &Attrs::with_axis(-9), &[&s]).is_err());
        assert!(infer_shape(OpKind::Mean, &Attrs::with_axis(3), &[&s]).is_err());
    }

    #[test]
    fn hostile_dims_error_instead_of_wrapping() {
        let huge = vec![usize::MAX / 2, 8];
        assert!(checked_numel(&huge).is_err());
        // beyond the element cap but no usize overflow
        assert!(checked_numel(&[1 << 20, 1 << 20]).is_err());
        assert!(checked_numel(&[1, 3, 224, 224]).is_ok());
        // saturating numel never wraps to a tiny value
        assert_eq!(numel(&huge), usize::MAX);
        // flatten of an overflowing shape errors
        assert!(infer_shape(OpKind::Flatten, &Attrs::none(), &[&huge.clone()]).is_err());
        // conv with absurd padding errors
        let a = Attrs::conv(64, 3, 1, usize::MAX / 2 + 1, 1);
        assert!(infer_shape(OpKind::Conv2d, &a, &[&vec![1, 3, 8, 8]]).is_err());
        // zero stride errors instead of dividing by zero
        let mut z = Attrs::conv(64, 3, 1, 1, 1);
        z.strides = Some((0, 0));
        assert!(infer_shape(OpKind::Conv2d, &z, &[&vec![1, 3, 8, 8]]).is_err());
        // weight-count overflow is caught
        assert!(checked_weight_count(
            OpKind::Dense,
            &Attrs::dense(usize::MAX / 2),
            &[1, usize::MAX / 2],
            &[1, usize::MAX / 2],
        )
        .is_err());
    }

    #[test]
    fn weight_counts() {
        // conv 3->64, 7x7: 64*3*49 + 64
        assert_eq!(
            weight_count(
                OpKind::Conv2d,
                &Attrs::conv(64, 7, 2, 3, 1),
                &[1, 3, 224, 224],
                &[1, 64, 112, 112]
            ),
            64 * 3 * 49 + 64
        );
        assert_eq!(
            weight_count(OpKind::Dense, &Attrs::dense(10), &[1, 512], &[1, 10]),
            512 * 10 + 10
        );
        assert_eq!(weight_count(OpKind::Relu, &Attrs::none(), &[1, 8], &[1, 8]), 0);
    }
}
