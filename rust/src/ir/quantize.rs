//! Graph-rewrite pass emitting quantized variants of a graph for
//! design-space-exploration sweeps: "what do I save at fp16 / int8?".
//!
//! The rewrite is a pure metadata pass — shapes and topology are
//! untouched; every node's `attrs.dtype` is set to the target dtype (a
//! whole-graph cast, the way TensorRT's `--fp16` / `--int8` builder flags
//! or torch `.half()` convert a model). The variant tag is suffixed so
//! dataset entries and logs stay distinguishable; fingerprints diverge
//! automatically because dtype folds into the WL signatures.

use super::dtype::{DType, ALL_DTYPES};
use super::graph::Graph;

/// Rewrite `graph` to a uniformly `dtype`-typed variant.
///
/// Casting to [`DType::F32`] returns a graph bit-identical to the input
/// except for any nodes that were non-fp32 (the tag is only suffixed for
/// non-fp32 targets, so fp32-in → fp32-out is a true no-op).
pub fn quantize(graph: &Graph, dtype: DType) -> Graph {
    let mut g = graph.clone();
    for n in g.nodes.iter_mut() {
        n.attrs.dtype = dtype;
    }
    if dtype != DType::F32 {
        let suffix = format!("-{}", dtype.name());
        if !g.variant.ends_with(&suffix) {
            g.variant.push_str(&suffix);
        }
    } else {
        // Strip a previous quantize suffix when casting back to fp32 so
        // quantize(quantize(g, X), F32) round-trips to g.
        for dt in ALL_DTYPES {
            if dt == DType::F32 {
                continue;
            }
            let suffix = format!("-{}", dt.name());
            if let Some(stripped) = g.variant.strip_suffix(&suffix) {
                g.variant = stripped.to_string();
                break;
            }
        }
    }
    g
}

/// All dtype variants of a graph (fp32 first), for DSE sweeps over the
/// quantization axis.
pub fn dtype_sweep(graph: &Graph) -> Vec<Graph> {
    ALL_DTYPES.iter().map(|&dt| quantize(graph, dt)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::ir::OpKind;

    fn tiny() -> Graph {
        let mut b = GraphBuilder::new("test", "tiny", 1);
        let x = b.input(vec![1, 3, 16, 16]);
        let c = b.conv_relu(x, 8, 3, 1, 1);
        let p = b.add(OpKind::GlobalAvgPool2d, crate::ir::Attrs::none(), &[c]);
        let f = b.add(OpKind::Flatten, crate::ir::Attrs::none(), &[p]);
        b.dense(f, 10);
        b.finish()
    }

    #[test]
    fn quantize_sets_every_node_and_stays_valid() {
        let g = tiny();
        let q = quantize(&g, DType::F16);
        assert!(q.validate().is_ok());
        assert!(q.nodes.iter().all(|n| n.attrs.dtype == DType::F16));
        assert_eq!(q.variant, "tiny-f16");
        // topology and shapes untouched
        assert_eq!(q.n_nodes(), g.n_nodes());
        for (a, b) in g.nodes.iter().zip(q.nodes.iter()) {
            assert_eq!(a.out_shape, b.out_shape);
            assert_eq!(a.op, b.op);
        }
    }

    #[test]
    fn f32_quantize_is_identity() {
        let g = tiny();
        assert_eq!(quantize(&g, DType::F32), g);
    }

    #[test]
    fn quantize_roundtrips_through_f32() {
        let g = tiny();
        let q = quantize(&quantize(&g, DType::I8), DType::F32);
        assert_eq!(q, g);
    }

    #[test]
    fn sweep_covers_all_dtypes_distinctly() {
        let g = tiny();
        let sweep = dtype_sweep(&g);
        assert_eq!(sweep.len(), ALL_DTYPES.len());
        assert_eq!(sweep[0], g); // fp32 first, unchanged
        let mut sigs: Vec<Vec<u64>> =
            sweep.iter().map(|v| v.canonical_signatures()).collect();
        sigs.sort();
        sigs.dedup();
        assert_eq!(sigs.len(), ALL_DTYPES.len(), "dtype variants must not collide");
    }
}
