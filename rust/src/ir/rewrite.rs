//! Structural rewrite passes for design-space-exploration sweeps: scale a
//! model's width (channel/unit counts), depth (replicate shape-preserving
//! weighted layers) or batch size, producing new *valid* graphs whose
//! shapes are re-derived through [`infer_shape`] node by node.
//!
//! Together with [`super::quantize`] these are the mutation axes of the
//! server-side `Sweep` verb: the client ships one base graph plus grids of
//! `(depth, width, batch, dtype)` knobs and the server expands the cross
//! product locally. Every pass is deterministic and total over its inputs:
//! a knob combination the architecture cannot support (e.g. width-scaling
//! a residual branch anchored on the unscaled input) returns a
//! per-candidate `Err` instead of panicking or emitting an invalid graph.

use super::graph::{Graph, Node};
use super::infer::{infer_shape, numel, Shape};
use super::op::OpKind;

/// Scale the width (conv output channels / dense units) of every weighted
/// layer to `percent`% of its original size, rounding to the nearest unit
/// with a floor of 1. The final classifier head — a `Dense` sink — keeps
/// its units (class count is not a width knob). Depthwise convolutions
/// re-sync `groups` to their (scaled) input channel count. `percent ==
/// 100` is the identity (a plain clone, same variant tag).
pub fn scale_width(graph: &Graph, percent: usize) -> Result<Graph, String> {
    if percent == 0 {
        return Err("width percent must be >= 1".into());
    }
    if percent == 100 {
        return Ok(graph.clone());
    }
    let consumers = graph.consumers();
    let mut nodes: Vec<Node> = Vec::with_capacity(graph.nodes.len());
    for n in &graph.nodes {
        let mut node = n.clone();
        match n.op {
            OpKind::Input => {}
            OpKind::Reshape | OpKind::Transpose | OpKind::StridedSlice => {
                let old_in = numel(&graph.nodes[n.inputs[0]].out_shape);
                let new_in = numel(&nodes[n.inputs[0]].out_shape);
                node.out_shape =
                    rescale_opaque(n.op, &n.out_shape, old_in, new_in, &[1])?;
            }
            _ => {
                // The classifier head keeps its class count; every other
                // units-bearing op scales.
                let head = n.op == OpKind::Dense && consumers[n.id].is_empty();
                if !head {
                    if let Some(u) = node.attrs.units {
                        node.attrs.units = Some(scale_units(u, percent)?);
                    }
                }
                if n.op == OpKind::DepthwiseConv2d {
                    node.attrs.groups = nodes[n.inputs[0]].out_shape[1];
                }
                let shapes: Vec<&Shape> =
                    n.inputs.iter().map(|&s| &nodes[s].out_shape).collect();
                node.out_shape = infer_shape(n.op, &node.attrs, &shapes).map_err(|e| {
                    format!("width {percent}% fails at node {} ({}): {e}", n.id, n.op)
                })?;
            }
        }
        nodes.push(node);
    }
    finish(graph, nodes, graph.batch, format!("{}-w{percent}", graph.variant))
}

/// Deepen the model by replacing every *shape-preserving, single-input,
/// MAC-counting* node (e.g. a 3x3 stride-1 same-channel conv, a
/// square dense projection) with a chain of `repeat` copies. Graphs with
/// no such node come back structurally unchanged (the depth knob is a
/// no-op for them). `repeat == 1` is the identity.
pub fn scale_depth(graph: &Graph, repeat: usize) -> Result<Graph, String> {
    if repeat == 0 {
        return Err("depth repeat must be >= 1".into());
    }
    if repeat == 1 {
        return Ok(graph.clone());
    }
    let mut nodes: Vec<Node> = Vec::with_capacity(graph.nodes.len());
    let mut map = vec![0usize; graph.nodes.len()];
    for n in &graph.nodes {
        let mut node = n.clone();
        node.inputs = n.inputs.iter().map(|&s| map[s]).collect();
        node.id = nodes.len();
        let replicate = n.inputs.len() == 1
            && n.op.counts_macs()
            && n.out_shape == graph.nodes[n.inputs[0]].out_shape;
        let mut last = node.id;
        nodes.push(node);
        if replicate {
            for r in 1..repeat {
                let id = nodes.len();
                let mut copy = n.clone();
                copy.id = id;
                copy.inputs = vec![last];
                copy.name = format!("{}_d{r}", n.name);
                nodes.push(copy);
                last = id;
            }
        }
        map[n.id] = last;
    }
    finish(graph, nodes, graph.batch, format!("{}-d{repeat}", graph.variant))
}

/// Re-batch the graph: every `Input` node's leading dimension (and the
/// graph's `batch` field) becomes `batch`, and all downstream shapes are
/// re-derived. Rebatching to the current batch is the identity.
pub fn rebatch(graph: &Graph, batch: usize) -> Result<Graph, String> {
    if batch == 0 {
        return Err("batch must be >= 1".into());
    }
    if batch == graph.batch {
        return Ok(graph.clone());
    }
    let mut nodes: Vec<Node> = Vec::with_capacity(graph.nodes.len());
    for n in &graph.nodes {
        let mut node = n.clone();
        match n.op {
            OpKind::Input => {
                node.out_shape[0] = batch;
            }
            OpKind::Reshape | OpKind::Transpose | OpKind::StridedSlice => {
                let old_in = numel(&graph.nodes[n.inputs[0]].out_shape);
                let new_in = numel(&nodes[n.inputs[0]].out_shape);
                node.out_shape =
                    rescale_opaque(n.op, &n.out_shape, old_in, new_in, &[0])?;
            }
            _ => {
                let shapes: Vec<&Shape> =
                    n.inputs.iter().map(|&s| &nodes[s].out_shape).collect();
                node.out_shape = infer_shape(n.op, &node.attrs, &shapes).map_err(|e| {
                    format!("rebatch to {batch} fails at node {} ({}): {e}", n.id, n.op)
                })?;
            }
        }
        nodes.push(node);
    }
    finish(graph, nodes, batch, format!("{}-b{batch}", graph.variant))
}

/// Nearest-unit scaling with a floor of 1 and an overflow check.
fn scale_units(units: usize, percent: usize) -> Result<usize, String> {
    units
        .checked_mul(percent)
        .map(|p| ((p + 50) / 100).max(1))
        .ok_or_else(|| format!("width {percent}% of {units} units overflows"))
}

/// Rescale the out-of-band target shape of a reshape-family node whose
/// input element count changed from `old_in` to `new_in`: scale exactly
/// one dimension by the same ratio (trying `prefer`red dims first, then
/// the rest) so the element-count invariant survives. Errors when no
/// single dimension divides cleanly — that candidate is unsupported.
fn rescale_opaque(
    op: OpKind,
    old_out: &Shape,
    old_in: usize,
    new_in: usize,
    prefer: &[usize],
) -> Result<Shape, String> {
    if old_in == new_in {
        return Ok(old_out.to_vec());
    }
    let mut order: Vec<usize> = prefer.iter().copied().filter(|&d| d < old_out.len()).collect();
    for d in 0..old_out.len() {
        if !order.contains(&d) {
            order.push(d);
        }
    }
    for d in order {
        if let Some(p) = old_out[d].checked_mul(new_in) {
            if old_in > 0 && p % old_in == 0 && p / old_in >= 1 {
                let mut out = old_out.to_vec();
                out[d] = p / old_in;
                return Ok(out);
            }
        }
    }
    Err(format!(
        "cannot rescale {op} target {old_out:?} from {old_in} to {new_in} elements"
    ))
}

/// Assemble and validate the rewritten graph. Validation is the safety
/// net: a pass bug (or an architecture the ratio heuristics cannot carry)
/// surfaces as a per-candidate error here, never as an invalid graph
/// escaping into the admission path.
fn finish(base: &Graph, nodes: Vec<Node>, batch: usize, variant: String) -> Result<Graph, String> {
    let g = Graph {
        nodes,
        batch,
        family: base.family.clone(),
        variant,
    };
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Attrs, GraphBuilder};

    /// conv -> relu -> (shape-preserving conv) -> pool -> flatten -> dense
    fn tiny() -> Graph {
        let mut b = GraphBuilder::new("test", "tiny", 2);
        let x = b.input(vec![2, 3, 16, 16]);
        let c1 = b.conv_relu(x, 8, 3, 1, 1);
        let c2 = b.conv2d(c1, 8, 3, 1, 1); // 8 -> 8, stride 1: shape-preserving
        let p = b.add(OpKind::GlobalAvgPool2d, Attrs::none(), &[c2]);
        let f = b.add(OpKind::Flatten, Attrs::none(), &[p]);
        b.dense(f, 10);
        b.finish()
    }

    fn residual_from_input() -> Graph {
        let mut b = GraphBuilder::new("test", "skip", 1);
        let x = b.input(vec![1, 8, 8, 8]);
        let c = b.conv2d(x, 8, 3, 1, 1);
        b.add(OpKind::Add, Attrs::none(), &[c, x]);
        b.finish()
    }

    #[test]
    fn width_100_is_identity() {
        let g = tiny();
        assert_eq!(scale_width(&g, 100).unwrap(), g);
    }

    #[test]
    fn width_scales_channels_but_not_the_head() {
        let g = tiny();
        let half = scale_width(&g, 50).unwrap();
        assert!(half.validate().is_ok());
        assert_eq!(half.nodes[1].attrs.units, Some(4), "conv channels halved");
        assert_eq!(half.nodes[1].out_shape[1], 4);
        let head = half.nodes.last().unwrap();
        assert_eq!(head.attrs.units, Some(10), "classifier keeps its classes");
        assert_eq!(half.variant, "tiny-w50");
        // Fingerprints diverge from the base.
        assert_ne!(half.canonical_signatures(), g.canonical_signatures());
    }

    #[test]
    fn width_floor_is_one_unit() {
        let g = tiny();
        let slim = scale_width(&g, 1).unwrap();
        assert!(slim.nodes[1].attrs.units.unwrap() >= 1);
        assert!(slim.validate().is_ok());
    }

    #[test]
    fn width_resyncs_depthwise_groups() {
        let mut b = GraphBuilder::new("test", "dw", 1);
        let x = b.input(vec![1, 3, 16, 16]);
        let c = b.conv2d(x, 32, 3, 1, 1);
        b.depthwise(c, 3, 1, 1);
        let g = b.finish();
        let half = scale_width(&g, 50).unwrap();
        assert_eq!(half.nodes[1].out_shape[1], 16);
        assert_eq!(half.nodes[2].attrs.groups, 16, "depthwise groups follow C_in");
        assert!(half.validate().is_ok());
    }

    #[test]
    fn width_rejects_residual_anchored_on_input() {
        // The skip branch keeps the input's 8 channels while the conv
        // branch scales — an architecture the width knob cannot support.
        let g = residual_from_input();
        assert!(scale_width(&g, 50).is_err());
    }

    #[test]
    fn width_scales_residuals_between_scaled_branches() {
        let mut b = GraphBuilder::new("test", "res", 1);
        let x = b.input(vec![1, 3, 8, 8]);
        let c1 = b.conv2d(x, 16, 3, 1, 1);
        let c2 = b.conv2d(c1, 16, 3, 1, 1);
        let s = b.add(OpKind::Add, Attrs::none(), &[c1, c2]);
        b.relu(s);
        let g = b.finish();
        let wide = scale_width(&g, 200).unwrap();
        assert_eq!(wide.nodes[1].out_shape[1], 32);
        assert!(wide.validate().is_ok());
    }

    #[test]
    fn depth_1_is_identity() {
        let g = tiny();
        assert_eq!(scale_depth(&g, 1).unwrap(), g);
    }

    #[test]
    fn depth_replicates_shape_preserving_weighted_nodes() {
        let g = tiny();
        let deep = scale_depth(&g, 3).unwrap();
        assert!(deep.validate().is_ok());
        // Exactly one node qualifies (the 8->8 conv); 2 copies appended.
        assert_eq!(deep.n_nodes(), g.n_nodes() + 2);
        assert_eq!(deep.count_op(OpKind::Conv2d), 4);
        assert_eq!(deep.variant, "tiny-d3");
        assert!(deep.total_weights() > g.total_weights());
        assert_ne!(deep.canonical_signatures().len(), g.canonical_signatures().len());
    }

    #[test]
    fn depth_without_qualifying_nodes_is_structurally_unchanged() {
        // conv 3->8 changes channels; dense head is a sink but changes
        // features — nothing replicates.
        let mut b = GraphBuilder::new("test", "flat", 1);
        let x = b.input(vec![1, 3, 8, 8]);
        let c = b.conv2d(x, 8, 3, 2, 1);
        let f = b.add(OpKind::Flatten, Attrs::none(), &[c]);
        b.dense(f, 10);
        let g = b.finish();
        let deep = scale_depth(&g, 4).unwrap();
        assert_eq!(deep.n_nodes(), g.n_nodes());
        // Same structure, same fingerprints: the sweep's intra-request
        // dedup collapses this candidate onto the base.
        assert_eq!(deep.canonical_signatures(), g.canonical_signatures());
    }

    #[test]
    fn rebatch_changes_every_leading_dim() {
        let g = tiny();
        let b8 = rebatch(&g, 8).unwrap();
        assert!(b8.validate().is_ok());
        assert_eq!(b8.batch, 8);
        for n in &b8.nodes {
            assert_eq!(n.out_shape[0], 8, "node {} kept the old batch", n.id);
        }
        assert_eq!(rebatch(&g, 2).unwrap(), g, "same batch is the identity");
    }

    #[test]
    fn passes_compose() {
        let g = tiny();
        let c = rebatch(&scale_width(&scale_depth(&g, 2).unwrap(), 50).unwrap(), 4).unwrap();
        assert!(c.validate().is_ok());
        assert_eq!(c.batch, 4);
        assert_eq!(c.variant, "tiny-d2-w50-b4");
    }

    #[test]
    fn zero_knobs_are_rejected() {
        let g = tiny();
        assert!(scale_width(&g, 0).is_err());
        assert!(scale_depth(&g, 0).is_err());
        assert!(rebatch(&g, 0).is_err());
    }
}
