//! Operator vocabulary and attributes.
//!
//! The operator set covers everything the ten model families of paper
//! Table 2 need after inference simplification (BatchNorm folding happens in
//! the generators/frontends, but BatchNorm remains representable because
//! real framework exports may contain it).

use std::fmt;

use super::dtype::DType;

/// Operator kinds. The one-hot *category* used in node features groups
/// related kinds (see [`OpKind::category`]) to keep the paper's fixed
/// 32-feature budget (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    /// Graph input placeholder.
    Input,
    Conv2d,
    DepthwiseConv2d,
    Conv2dTranspose,
    /// Fully-connected / linear.
    Dense,
    /// Batched matrix multiply (attention scores/values).
    BatchMatmul,
    Relu,
    Gelu,
    Sigmoid,
    HardSwish,
    Softmax,
    Add,
    Multiply,
    Concat,
    MaxPool2d,
    AvgPool2d,
    GlobalAvgPool2d,
    BatchNorm,
    LayerNorm,
    Reshape,
    Transpose,
    Flatten,
    StridedSlice,
    /// Reduction mean over an axis (e.g. token pooling in transformers).
    Mean,
}

pub const ALL_OPS: [OpKind; 24] = [
    OpKind::Input,
    OpKind::Conv2d,
    OpKind::DepthwiseConv2d,
    OpKind::Conv2dTranspose,
    OpKind::Dense,
    OpKind::BatchMatmul,
    OpKind::Relu,
    OpKind::Gelu,
    OpKind::Sigmoid,
    OpKind::HardSwish,
    OpKind::Softmax,
    OpKind::Add,
    OpKind::Multiply,
    OpKind::Concat,
    OpKind::MaxPool2d,
    OpKind::AvgPool2d,
    OpKind::GlobalAvgPool2d,
    OpKind::BatchNorm,
    OpKind::LayerNorm,
    OpKind::Reshape,
    OpKind::Transpose,
    OpKind::Flatten,
    OpKind::StridedSlice,
    OpKind::Mean,
];

/// Number of one-hot categories in the node feature vector.
pub const N_CATEGORIES: usize = 18;

impl OpKind {
    /// Canonical lowercase name (used by the native text format and NFG).
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Input => "input",
            OpKind::Conv2d => "conv2d",
            OpKind::DepthwiseConv2d => "depthwise_conv2d",
            OpKind::Conv2dTranspose => "conv2d_transpose",
            OpKind::Dense => "dense",
            OpKind::BatchMatmul => "batch_matmul",
            OpKind::Relu => "relu",
            OpKind::Gelu => "gelu",
            OpKind::Sigmoid => "sigmoid",
            OpKind::HardSwish => "hard_swish",
            OpKind::Softmax => "softmax",
            OpKind::Add => "add",
            OpKind::Multiply => "multiply",
            OpKind::Concat => "concat",
            OpKind::MaxPool2d => "max_pool2d",
            OpKind::AvgPool2d => "avg_pool2d",
            OpKind::GlobalAvgPool2d => "global_avg_pool2d",
            OpKind::BatchNorm => "batch_norm",
            OpKind::LayerNorm => "layer_norm",
            OpKind::Reshape => "reshape",
            OpKind::Transpose => "transpose",
            OpKind::Flatten => "flatten",
            OpKind::StridedSlice => "strided_slice",
            OpKind::Mean => "mean",
        }
    }

    pub fn from_name(name: &str) -> Option<OpKind> {
        ALL_OPS.iter().copied().find(|op| op.name() == name)
    }

    /// One-hot category index for the NFG (groups related ops; paper §3.2
    /// fixes the feature length at 32 = 18 categories + 6 attrs + 8 shape).
    pub fn category(self) -> usize {
        match self {
            OpKind::Input => 0,
            OpKind::Conv2d => 1,
            OpKind::DepthwiseConv2d => 2,
            OpKind::Conv2dTranspose => 3,
            OpKind::Dense => 4,
            OpKind::BatchMatmul => 5,
            OpKind::Relu => 6,
            OpKind::Gelu | OpKind::Sigmoid | OpKind::HardSwish => 7,
            OpKind::Softmax => 8,
            OpKind::Add => 9,
            OpKind::Multiply => 10,
            OpKind::Concat => 11,
            OpKind::MaxPool2d | OpKind::AvgPool2d => 12,
            OpKind::GlobalAvgPool2d => 13,
            OpKind::BatchNorm => 14,
            OpKind::LayerNorm => 15,
            OpKind::Reshape
            | OpKind::Transpose
            | OpKind::Flatten
            | OpKind::StridedSlice => 16,
            OpKind::Mean => 17,
        }
    }

    /// Does this op carry trainable weights (contributes to model size)?
    pub fn has_weights(self) -> bool {
        matches!(
            self,
            OpKind::Conv2d
                | OpKind::DepthwiseConv2d
                | OpKind::Conv2dTranspose
                | OpKind::Dense
                | OpKind::BatchNorm
                | OpKind::LayerNorm
        )
    }

    /// Elementwise ops are fusable into their producer (simulator fusion
    /// pass) — they never cause an extra HBM round-trip on a real GPU.
    pub fn is_elementwise(self) -> bool {
        matches!(
            self,
            OpKind::Relu
                | OpKind::Gelu
                | OpKind::Sigmoid
                | OpKind::HardSwish
                | OpKind::Add
                | OpKind::Multiply
                | OpKind::BatchNorm
        )
    }

    /// Tensor-core eligible (MXU-analogue) ops (simulator roofline).
    pub fn is_tensor_core(self) -> bool {
        matches!(
            self,
            OpKind::Conv2d | OpKind::Conv2dTranspose | OpKind::Dense | OpKind::BatchMatmul
        )
    }

    /// MACs counted by the SFG, mirroring TVM's relay analysis which only
    /// counts Conv2D / Conv2D-transpose / dense / batch_matmul (paper §3.3).
    pub fn counts_macs(self) -> bool {
        matches!(
            self,
            OpKind::Conv2d
                | OpKind::DepthwiseConv2d
                | OpKind::Conv2dTranspose
                | OpKind::Dense
                | OpKind::BatchMatmul
        )
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Operator attributes. A closed struct (not a map) keeps featurization
/// total and cheap; unused fields are zero/None for a given op.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Attrs {
    /// Convolution / pooling kernel (kh, kw).
    pub kernel: Option<(usize, usize)>,
    /// Strides (sh, sw).
    pub strides: Option<(usize, usize)>,
    /// Symmetric spatial padding.
    pub padding: usize,
    /// Convolution groups (1 = dense conv; = C_in for depthwise).
    pub groups: usize,
    /// Dense units / conv output channels.
    pub units: Option<usize>,
    /// Axis for concat/softmax/mean.
    pub axis: Option<i64>,
    /// Element dtype of this node's output (and weights). Defaults to
    /// [`DType::F32`], the pre-dtype-era behavior.
    pub dtype: DType,
}

impl Attrs {
    pub fn none() -> Attrs {
        Attrs {
            groups: 1,
            ..Default::default()
        }
    }

    /// This attrs set, re-typed to `dtype`.
    pub fn with_dtype(mut self, dtype: DType) -> Attrs {
        self.dtype = dtype;
        self
    }

    pub fn conv(out_ch: usize, k: usize, s: usize, pad: usize, groups: usize) -> Attrs {
        Attrs {
            kernel: Some((k, k)),
            strides: Some((s, s)),
            padding: pad,
            groups,
            units: Some(out_ch),
            axis: None,
            dtype: DType::F32,
        }
    }

    pub fn pool(k: usize, s: usize, pad: usize) -> Attrs {
        Attrs {
            kernel: Some((k, k)),
            strides: Some((s, s)),
            padding: pad,
            groups: 1,
            units: None,
            axis: None,
            dtype: DType::F32,
        }
    }

    pub fn dense(units: usize) -> Attrs {
        Attrs {
            units: Some(units),
            groups: 1,
            ..Default::default()
        }
    }

    pub fn with_axis(axis: i64) -> Attrs {
        Attrs {
            axis: Some(axis),
            groups: 1,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for op in ALL_OPS {
            assert_eq!(OpKind::from_name(op.name()), Some(op), "{op}");
        }
        assert_eq!(OpKind::from_name("bogus"), None);
    }

    #[test]
    fn categories_within_bounds() {
        for op in ALL_OPS {
            assert!(op.category() < N_CATEGORIES, "{op}");
        }
    }

    #[test]
    fn every_category_used() {
        let mut used = [false; N_CATEGORIES];
        for op in ALL_OPS {
            used[op.category()] = true;
        }
        assert!(used.iter().all(|&u| u));
    }

    #[test]
    fn tensor_core_ops_count_macs() {
        for op in ALL_OPS {
            if op.is_tensor_core() {
                assert!(op.counts_macs(), "{op}");
            }
        }
    }

    #[test]
    fn attr_constructors() {
        let a = Attrs::conv(64, 3, 2, 1, 1);
        assert_eq!(a.kernel, Some((3, 3)));
        assert_eq!(a.units, Some(64));
        assert_eq!(Attrs::dense(10).units, Some(10));
        assert_eq!(Attrs::none().groups, 1);
    }
}
