//! Generalized graph IR (the paper's "Relay IR" analogue, §3.1).
//!
//! Every frontend lowers into this representation; the featurizers
//! (Algorithm 1 + eq. 1), the A100 simulator and the model generators all
//! speak it. A [`Graph`] is a DAG of operator [`Node`]s over NCHW tensors,
//! stored in topological order (enforced at construction / validation).

pub mod dtype;
pub mod graph;
pub mod infer;
pub mod op;
pub mod quantize;
pub mod rewrite;

pub use dtype::{DType, ALL_DTYPES};
pub use graph::{Graph, GraphBuilder, Node, NodeId};
pub use op::{Attrs, OpKind};
pub use rewrite::{rebatch, scale_depth, scale_width};
