//! The graph structure: a DAG of operator nodes in topological order, plus
//! the [`GraphBuilder`] the model generators and frontends use to construct
//! valid graphs (shape inference runs at every `add`).

use super::dtype::DType;
use super::infer::{checked_numel, checked_weight_count, infer_shape, numel, weight_count, Shape};
use super::op::{Attrs, OpKind};
use crate::util::rng::splitmix64;

pub type NodeId = usize;

/// One operator node. `inputs` reference earlier nodes only (topological
/// order is a construction invariant, checked by [`Graph::validate`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub op: OpKind,
    pub attrs: Attrs,
    pub inputs: Vec<NodeId>,
    pub out_shape: Shape,
    /// Human-readable name (layer path in the source framework).
    pub name: String,
}

/// A model graph: the IR every frontend lowers into (paper §3.1).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Graph {
    pub nodes: Vec<Node>,
    /// Inference batch size (also a static feature, paper eq. 1).
    pub batch: usize,
    /// Family tag, e.g. "resnet" — metadata for the dataset distribution.
    pub family: String,
    /// Variant tag, e.g. "resnet34-r224-b16".
    pub variant: String,
}

impl Graph {
    /// Number of operator nodes (excludes nothing — Input is an operator
    /// node in our encoding, as in the paper's relay post-order walk).
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Directed edge list (src, dst).
    pub fn edges(&self) -> Vec<(NodeId, NodeId)> {
        let mut e = Vec::new();
        for n in &self.nodes {
            for &src in &n.inputs {
                e.push((src, n.id));
            }
        }
        e
    }

    /// Consumers of each node.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &src in &n.inputs {
                out[src].push(n.id);
            }
        }
        out
    }

    /// Validate the topological invariant, id contiguity, shape consistency
    /// and dangling inputs. Returns a description of the first violation.
    pub fn validate(&self) -> Result<(), String> {
        if self.batch == 0 {
            return Err("batch must be >= 1".into());
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(format!("node {i} has id {}", n.id));
            }
            for &src in &n.inputs {
                if src >= i {
                    return Err(format!(
                        "node {i} ({}) references non-earlier input {src}",
                        n.op
                    ));
                }
            }
            // Overflow-checked element and weight counts: hostile dims must
            // error here, not wrap downstream into bogus tiny costs.
            checked_numel(&n.out_shape).map_err(|e| format!("node {i} ({}): {e}", n.op))?;
            {
                let in_shape = n
                    .inputs
                    .first()
                    .map(|&s| self.nodes[s].out_shape.as_slice())
                    .unwrap_or(&[]);
                checked_weight_count(n.op, &n.attrs, in_shape, &n.out_shape)
                    .map_err(|e| format!("node {i}: {e}"))?;
            }
            if n.op == OpKind::Input {
                if !n.inputs.is_empty() {
                    return Err(format!("input node {i} has inputs"));
                }
                if n.out_shape.is_empty() {
                    return Err(format!("input node {i} lacks a shape"));
                }
                if n.out_shape[0] != self.batch {
                    return Err(format!(
                        "input node {i} batch {} != graph batch {}",
                        n.out_shape[0], self.batch
                    ));
                }
                continue;
            }
            // Reshape-family ops carry their own target shape, but must
            // not create elements out of thin air.
            if matches!(
                n.op,
                OpKind::Reshape | OpKind::Transpose | OpKind::Flatten | OpKind::StridedSlice
            ) {
                let in_n = numel(&self.nodes[n.inputs[0]].out_shape);
                let out_n = numel(&n.out_shape);
                let ok = match n.op {
                    OpKind::StridedSlice => out_n <= in_n,
                    _ => out_n == in_n,
                };
                if !ok {
                    return Err(format!(
                        "node {i} ({}) element count {out_n} inconsistent with input {in_n}",
                        n.op
                    ));
                }
                continue;
            }
            let in_shapes: Vec<&Shape> =
                n.inputs.iter().map(|&s| &self.nodes[s].out_shape).collect();
            let expect = infer_shape(n.op, &n.attrs, &in_shapes)
                .map_err(|e| format!("node {i} ({}): {e}", n.op))?;
            if expect != n.out_shape {
                return Err(format!(
                    "node {i} ({}) shape {:?} != inferred {:?}",
                    n.op, n.out_shape, expect
                ));
            }
        }
        Ok(())
    }

    /// Post-order traversal from sinks (paper Algorithm 1 filters the relay
    /// IR by post-order walk). With nodes already topologically ordered this
    /// visits every node reachable from a sink, children before parents.
    pub fn post_order(&self) -> Vec<NodeId> {
        let consumers = self.consumers();
        let sinks: Vec<NodeId> = (0..self.nodes.len())
            .filter(|&i| consumers[i].is_empty())
            .collect();
        let mut visited = vec![false; self.nodes.len()];
        let mut order = Vec::with_capacity(self.nodes.len());
        // Iterative DFS with explicit post-visit marker.
        let mut stack: Vec<(NodeId, bool)> = sinks.iter().rev().map(|&s| (s, false)).collect();
        while let Some((id, post)) = stack.pop() {
            if post {
                order.push(id);
                continue;
            }
            if visited[id] {
                continue;
            }
            visited[id] = true;
            stack.push((id, true));
            for &src in self.nodes[id].inputs.iter().rev() {
                if !visited[src] {
                    stack.push((src, false));
                }
            }
        }
        order
    }

    /// Total trainable parameters (for model-size / memory accounting).
    pub fn total_weights(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| {
                let in_shape = n
                    .inputs
                    .first()
                    .map(|&s| self.nodes[s].out_shape.as_slice())
                    .unwrap_or(&[]);
                weight_count(n.op, &n.attrs, in_shape, &n.out_shape)
            })
            .sum()
    }

    /// Count of nodes of a given kind (SFG features, paper eq. 1).
    pub fn count_op(&self, op: OpKind) -> usize {
        self.nodes.iter().filter(|n| n.op == op).count()
    }

    /// Canonical per-node structural signatures via Weisfeiler–Lehman-style
    /// color refinement: each node starts from a hash of its semantic
    /// content (op kind, attributes, output shape — never its id or name)
    /// and is refined for a few rounds by mixing in its ordered input
    /// signatures and its sorted consumer signatures.
    ///
    /// The result is invariant to node renaming and to any topology-
    /// preserving relabeling of node ids: isomorphic graphs produce the
    /// same multiset of signatures. This is the substrate of the serving
    /// cache's [`crate::cache::Fingerprint`].
    pub fn canonical_signatures(&self) -> Vec<u64> {
        fn mix(h: u64, v: u64) -> u64 {
            splitmix64(h.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ v)
        }
        fn local_signature(node: &Node) -> u64 {
            let mut h = 0xD1B2_C0DE_u64;
            for &b in node.op.name().as_bytes() {
                h = mix(h, b as u64);
            }
            let a = &node.attrs;
            let (kh, kw) = a.kernel.map_or((0, 0), |(x, y)| (x + 1, y + 1));
            let (sh, sw) = a.strides.map_or((0, 0), |(x, y)| (x + 1, y + 1));
            let units = a.units.map_or(0, |u| u + 1);
            // Axis is signed; shift into non-negative space deterministically.
            let axis = a.axis.map_or(0, |x| (x + 64) as u64 + 1);
            for v in [
                kh as u64,
                kw as u64,
                sh as u64,
                sw as u64,
                a.padding as u64,
                a.groups as u64,
                units as u64,
                axis,
            ] {
                h = mix(h, v);
            }
            h = mix(h, node.out_shape.len() as u64);
            for &d in &node.out_shape {
                h = mix(h, d as u64 + 1);
            }
            // Dtype folds into the signature — so fp16/int8 variants never
            // collide with fp32 in the cache — but ONLY when non-default:
            // fp32 graphs must keep their pre-dtype-era fingerprints
            // bit-identical (persisted caches, replication manifests).
            if a.dtype != DType::F32 {
                h = mix(h, 0xD7_17E0 ^ a.dtype.index() as u64);
            }
            h
        }

        let n = self.nodes.len();
        let mut sig: Vec<u64> = self.nodes.iter().map(local_signature).collect();
        let consumers = self.consumers();
        // Three rounds propagate context 3 hops in each direction — ample
        // to separate every practically distinct architecture while staying
        // O(rounds * edges) on the serving hot path.
        for round in 0..3u64 {
            let mut next = vec![0u64; n];
            for (i, node) in self.nodes.iter().enumerate() {
                let mut h = mix(sig[i], 0xA11C_E000 ^ round);
                // Input order is semantic (e.g. concat), so hash it ordered.
                for &src in &node.inputs {
                    h = mix(h, sig[src]);
                }
                // Consumer ids are labeling-dependent; sort their signatures
                // so the multiset is what gets hashed.
                let mut cons: Vec<u64> = consumers[i].iter().map(|&c| sig[c]).collect();
                cons.sort_unstable();
                for c in cons {
                    h = mix(h, c.rotate_left(32));
                }
                next[i] = h;
            }
            sig = next;
        }
        sig
    }
}

/// Builder used by modelgen and the frontends. Every `add` runs shape
/// inference, so an invalid architecture fails at construction, not later.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    graph: Graph,
}

impl GraphBuilder {
    pub fn new(family: &str, variant: &str, batch: usize) -> GraphBuilder {
        GraphBuilder {
            graph: Graph {
                nodes: Vec::new(),
                batch,
                family: family.to_string(),
                variant: variant.to_string(),
            },
        }
    }

    pub fn input(&mut self, shape: Shape) -> NodeId {
        assert_eq!(shape[0], self.graph.batch, "input batch mismatch");
        self.push(OpKind::Input, Attrs::none(), vec![], shape, "input")
    }

    fn push(
        &mut self,
        op: OpKind,
        attrs: Attrs,
        inputs: Vec<NodeId>,
        out_shape: Shape,
        name: &str,
    ) -> NodeId {
        let id = self.graph.nodes.len();
        self.graph.nodes.push(Node {
            id,
            op,
            attrs,
            inputs,
            out_shape,
            name: format!("{name}_{id}"),
        });
        id
    }

    /// Generic add with shape inference.
    pub fn add(&mut self, op: OpKind, attrs: Attrs, inputs: &[NodeId]) -> NodeId {
        let shapes: Vec<&Shape> = inputs
            .iter()
            .map(|&i| &self.graph.nodes[i].out_shape)
            .collect();
        let out = infer_shape(op, &attrs, &shapes)
            .unwrap_or_else(|e| panic!("shape inference failed for {op}: {e}"));
        self.push(op, attrs, inputs.to_vec(), out, op.name())
    }

    /// Reshape-family add where the caller supplies the target shape.
    pub fn add_reshape(&mut self, op: OpKind, input: NodeId, out_shape: Shape) -> NodeId {
        debug_assert!(matches!(
            op,
            OpKind::Reshape | OpKind::Transpose | OpKind::StridedSlice
        ));
        self.push(op, Attrs::none(), vec![input], out_shape, op.name())
    }

    // --- common layer idioms used across families -----------------------

    pub fn conv2d(
        &mut self,
        input: NodeId,
        out_ch: usize,
        k: usize,
        s: usize,
        pad: usize,
    ) -> NodeId {
        self.add(OpKind::Conv2d, Attrs::conv(out_ch, k, s, pad, 1), &[input])
    }

    pub fn depthwise(&mut self, input: NodeId, k: usize, s: usize, pad: usize) -> NodeId {
        let c = self.shape(input)[1];
        let mut a = Attrs::conv(0, k, s, pad, c);
        a.units = None;
        self.add(OpKind::DepthwiseConv2d, a, &[input])
    }

    pub fn relu(&mut self, input: NodeId) -> NodeId {
        self.add(OpKind::Relu, Attrs::none(), &[input])
    }

    /// Conv (+folded BN) + ReLU — the inference-simplified conv block.
    pub fn conv_relu(
        &mut self,
        input: NodeId,
        out_ch: usize,
        k: usize,
        s: usize,
        pad: usize,
    ) -> NodeId {
        let c = self.conv2d(input, out_ch, k, s, pad);
        self.relu(c)
    }

    pub fn dense(&mut self, input: NodeId, units: usize) -> NodeId {
        self.add(OpKind::Dense, Attrs::dense(units), &[input])
    }

    pub fn shape(&self, id: NodeId) -> &Shape {
        &self.graph.nodes[id].out_shape
    }

    pub fn n_nodes(&self) -> usize {
        self.graph.nodes.len()
    }

    pub fn finish(self) -> Graph {
        debug_assert!(self.graph.validate().is_ok(), "{:?}", self.graph.validate());
        self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut b = GraphBuilder::new("test", "tiny", 2);
        let x = b.input(vec![2, 3, 32, 32]);
        let c = b.conv_relu(x, 8, 3, 1, 1);
        let p = b.add(OpKind::GlobalAvgPool2d, Attrs::none(), &[c]);
        let f = b.add(OpKind::Flatten, Attrs::none(), &[p]);
        b.dense(f, 10);
        b.finish()
    }

    #[test]
    fn builder_produces_valid_graph() {
        let g = tiny();
        assert_eq!(g.n_nodes(), 6);
        assert!(g.validate().is_ok());
        assert_eq!(g.nodes.last().unwrap().out_shape, vec![2, 10]);
    }

    #[test]
    fn validate_catches_forward_reference() {
        let mut g = tiny();
        g.nodes[1].inputs = vec![3]; // conv now depends on a later node
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_catches_wrong_shape() {
        let mut g = tiny();
        g.nodes[1].out_shape = vec![2, 9, 32, 32];
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_batch() {
        let mut g = tiny();
        g.batch = 4; // input node still has batch 2
        assert!(g.validate().is_err());
    }

    #[test]
    fn post_order_children_before_parents() {
        let g = tiny();
        let order = g.post_order();
        assert_eq!(order.len(), g.n_nodes());
        let pos: Vec<usize> = {
            let mut p = vec![0; order.len()];
            for (i, &id) in order.iter().enumerate() {
                p[id] = i;
            }
            p
        };
        for n in &g.nodes {
            for &src in &n.inputs {
                assert!(pos[src] < pos[n.id], "src {src} after node {}", n.id);
            }
        }
    }

    #[test]
    fn edges_and_consumers_agree() {
        let g = tiny();
        let edges = g.edges();
        let consumers = g.consumers();
        assert_eq!(edges.len(), consumers.iter().map(|c| c.len()).sum::<usize>());
        assert_eq!(edges.len(), 5);
    }

    #[test]
    fn weights_counted() {
        let g = tiny();
        // conv 3->8 3x3 (+bias) + dense 8->10 (+bias)
        assert_eq!(g.total_weights(), 8 * 3 * 9 + 8 + 8 * 10 + 10);
    }

    #[test]
    fn count_op_matches() {
        let g = tiny();
        assert_eq!(g.count_op(OpKind::Conv2d), 1);
        assert_eq!(g.count_op(OpKind::Relu), 1);
        assert_eq!(g.count_op(OpKind::Dense), 1);
        assert_eq!(g.count_op(OpKind::BatchMatmul), 0);
    }

    #[test]
    fn canonical_signatures_ignore_names() {
        let a = tiny();
        let mut b = tiny();
        for (i, n) in b.nodes.iter_mut().enumerate() {
            n.name = format!("renamed/{i}");
        }
        b.family = "other-family".into();
        b.variant = "other-variant".into();
        assert_eq!(a.canonical_signatures(), b.canonical_signatures());
    }

    #[test]
    fn canonical_signatures_see_attr_changes() {
        let a = tiny();
        let mut b = tiny();
        b.nodes[1].attrs.padding += 1;
        assert_ne!(a.canonical_signatures(), b.canonical_signatures());
    }

    #[test]
    fn canonical_signatures_distinguish_structure() {
        // Same node multiset, different wiring: add(x, c2) vs add(c1, c2)
        // is captured by the refinement rounds.
        let build = |skip_from_input: bool| {
            let mut b = GraphBuilder::new("t", "wiring", 1);
            let x = b.input(vec![1, 8, 8, 8]);
            let c1 = b.conv2d(x, 8, 3, 1, 1);
            let c2 = b.conv2d(c1, 8, 3, 1, 1);
            let lhs = if skip_from_input { x } else { c1 };
            b.add(OpKind::Add, Attrs::none(), &[lhs, c2]);
            b.finish()
        };
        let mut sa = build(true).canonical_signatures();
        let mut sb = build(false).canonical_signatures();
        sa.sort_unstable();
        sb.sort_unstable();
        assert_ne!(sa, sb);
    }

    #[test]
    fn dtype_changes_signatures_but_f32_is_legacy() {
        let a = tiny();
        let mut b = tiny();
        for n in b.nodes.iter_mut() {
            n.attrs.dtype = DType::F16;
        }
        assert_ne!(a.canonical_signatures(), b.canonical_signatures());
        let mut c = tiny();
        for n in c.nodes.iter_mut() {
            n.attrs.dtype = DType::I8;
        }
        assert_ne!(b.canonical_signatures(), c.canonical_signatures());
        // explicitly-f32 == default (pre-dtype) signatures
        let mut d = tiny();
        for n in d.nodes.iter_mut() {
            n.attrs.dtype = DType::F32;
        }
        assert_eq!(a.canonical_signatures(), d.canonical_signatures());
    }

    #[test]
    fn validate_rejects_overflowing_shapes() {
        let mut g = tiny();
        g.nodes[0].out_shape = vec![2, usize::MAX / 2, usize::MAX / 2];
        assert!(g.validate().is_err());
    }

    #[test]
    fn residual_block_via_add() {
        let mut b = GraphBuilder::new("test", "resblock", 1);
        let x = b.input(vec![1, 16, 8, 8]);
        let c1 = b.conv_relu(x, 16, 3, 1, 1);
        let c2 = b.conv2d(c1, 16, 3, 1, 1);
        let s = b.add(OpKind::Add, Attrs::none(), &[c2, x]);
        let r = b.relu(s);
        let g = b.finish();
        assert!(g.validate().is_ok());
        assert_eq!(g.nodes[r].out_shape, vec![1, 16, 8, 8]);
    }
}
