//! Element dtypes — first-class on every node so the simulator can price
//! quantized variants (fp16/bf16/int8) differently from fp32 and the cache
//! can keep their predictions apart.
//!
//! The default everywhere is [`DType::F32`]: graphs built by `modelgen`,
//! the text frontends, and every pre-dtype artifact stay fp32 and must
//! keep byte-identical costs, features, and fingerprints.

use std::fmt;

/// Tensor element type of a node's output (and its weights, if any).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    /// IEEE 754 single precision — the pre-dtype-era implicit default.
    #[default]
    F32,
    /// IEEE 754 half precision.
    F16,
    /// bfloat16 (same byte width as f16, wider exponent).
    BF16,
    /// 8-bit signed integer (post-training quantization).
    I8,
}

pub const ALL_DTYPES: [DType; 4] = [DType::F32, DType::F16, DType::BF16, DType::I8];

impl DType {
    /// Bytes per element. fp32 is exactly 4.0 — the value the whole
    /// simulator used as `BYTES_PER_ELEM` before dtypes existed.
    pub fn bytes(self) -> f64 {
        match self {
            DType::F32 => 4.0,
            DType::F16 | DType::BF16 => 2.0,
            DType::I8 => 1.0,
        }
    }

    /// Canonical lowercase name (native format, CLI flags).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::I8 => "i8",
        }
    }

    /// Parse a dtype name. Accepts our canonical names plus the common
    /// aliases used by ONNX/safetensors-adjacent tooling.
    pub fn from_name(s: &str) -> Option<DType> {
        match s {
            "f32" | "fp32" | "float32" | "float" | "F32" => Some(DType::F32),
            "f16" | "fp16" | "float16" | "half" | "F16" => Some(DType::F16),
            "bf16" | "bfloat16" | "BF16" => Some(DType::BF16),
            "i8" | "int8" | "I8" => Some(DType::I8),
            _ => None,
        }
    }

    /// Dtype one-hot index for node features (stable, matches `ALL_DTYPES`).
    pub fn index(self) -> usize {
        match self {
            DType::F32 => 0,
            DType::F16 => 1,
            DType::BF16 => 2,
            DType::I8 => 3,
        }
    }

    /// Map an ONNX `TensorProto.DataType` enum value. Unsupported element
    /// types (double, int64 weight indices, …) return `None` and callers
    /// decide whether that's an error or "ignore this tensor".
    pub fn from_onnx_elem(elem: u64) -> Option<DType> {
        match elem {
            1 => Some(DType::F32),
            10 => Some(DType::F16),
            16 => Some(DType::BF16),
            3 => Some(DType::I8),
            _ => None,
        }
    }

    /// ONNX `TensorProto.DataType` enum value for export.
    pub fn onnx_elem(self) -> u64 {
        match self {
            DType::F32 => 1,
            DType::F16 => 10,
            DType::BF16 => 16,
            DType::I8 => 3,
        }
    }

    /// Map a safetensors header dtype string ("F32", "F16", "BF16", "I8").
    pub fn from_safetensors(s: &str) -> Option<DType> {
        match s {
            "F32" => Some(DType::F32),
            "F16" => Some(DType::F16),
            "BF16" => Some(DType::BF16),
            "I8" => Some(DType::I8),
            _ => None,
        }
    }

    /// Safetensors header spelling.
    pub fn safetensors_name(self) -> &'static str {
        match self {
            DType::F32 => "F32",
            DType::F16 => "F16",
            DType::BF16 => "BF16",
            DType::I8 => "I8",
        }
    }

    /// Relative math-throughput multiplier vs fp32 on the simulated A100:
    /// half-width dtypes double tensor-core rates, int8 quadruples them
    /// (A100 peak: 312 TFLOPS fp16/bf16, 624 TOPS int8 vs 156 TFLOPS TF32).
    pub fn throughput_scale(self) -> f64 {
        match self {
            DType::F32 => 1.0,
            DType::F16 | DType::BF16 => 2.0,
            DType::I8 => 4.0,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_f32_with_legacy_width() {
        assert_eq!(DType::default(), DType::F32);
        assert_eq!(DType::F32.bytes(), 4.0);
        assert_eq!(DType::F32.throughput_scale(), 1.0);
    }

    #[test]
    fn names_roundtrip() {
        for dt in ALL_DTYPES {
            assert_eq!(DType::from_name(dt.name()), Some(dt), "{dt}");
            assert_eq!(DType::from_safetensors(dt.safetensors_name()), Some(dt));
            assert_eq!(DType::from_onnx_elem(dt.onnx_elem()), Some(dt));
        }
        assert_eq!(DType::from_name("f64"), None);
        assert_eq!(DType::from_onnx_elem(11), None); // double
    }

    #[test]
    fn indices_are_dense_and_stable() {
        for (i, dt) in ALL_DTYPES.iter().enumerate() {
            assert_eq!(dt.index(), i);
        }
    }

    #[test]
    fn narrower_dtypes_are_smaller_and_faster() {
        assert!(DType::F16.bytes() < DType::F32.bytes());
        assert!(DType::I8.bytes() < DType::F16.bytes());
        assert!(DType::I8.throughput_scale() > DType::F16.throughput_scale());
    }
}
