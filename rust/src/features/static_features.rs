//! Static Feature Generator — paper §3.3, eq. (1), plus the dtype mix:
//!
//! `F_s = F_mac ⊕ F_batch ⊕ F_Tconv ⊕ F_Tdense ⊕ F_Trelu ⊕ F_dtype[4]`
//!
//! The trailing four entries count nodes per dtype (fp32/fp16/bf16/int8, in
//! [`ALL_DTYPES`] order) so the MLP head can separate quantized variants.
//!
//! MACs follow the TVM relay analysis convention (conv2d, conv2d_transpose,
//! dense, batch_matmul — plus depthwise, which TVM counts as grouped conv).
//! Values are emitted *raw* here; normalization (log1p + z-score over the
//! training split) happens in `dataset::normalize` so serving can reuse the
//! exact training statistics.

use crate::ir::{Graph, OpKind, ALL_DTYPES};
use crate::simulator::cost::total_macs;

pub use crate::simulator::analysis::{EQ1_STATIC_FEATS, STATIC_FEATS};

/// Raw static features of a graph, in the paper's eq. (1) order.
///
/// This is the recompute-from-scratch path (one cost sweep); callers that
/// already hold a [`crate::simulator::GraphAnalysis`] read its `statics`
/// field instead — the two are bit-identical (parity property tests).
pub fn static_features(graph: &Graph) -> [f64; STATIC_FEATS] {
    let conv = graph.count_op(OpKind::Conv2d)
        + graph.count_op(OpKind::DepthwiseConv2d)
        + graph.count_op(OpKind::Conv2dTranspose);
    let mut dtype_counts = [0usize; ALL_DTYPES.len()];
    for n in &graph.nodes {
        dtype_counts[n.attrs.dtype.index()] += 1;
    }
    [
        total_macs(graph),
        graph.batch as f64,
        conv as f64,
        graph.count_op(OpKind::Dense) as f64,
        graph.count_op(OpKind::Relu) as f64,
        dtype_counts[0] as f64,
        dtype_counts[1] as f64,
        dtype_counts[2] as f64,
        dtype_counts[3] as f64,
    ]
}

/// Static features as exact integers for hashing (the cache fingerprint).
/// Every component of eq. (1) is an integral count (MACs, batch, op
/// counts), so rounding is exact and — unlike raw f64 bit patterns — the
/// result cannot depend on summation order. (The rounding itself lives in
/// `simulator::analysis`, next to the fingerprint fold that consumes it.)
pub fn static_feature_bits(statics: &[f64; STATIC_FEATS]) -> [u64; STATIC_FEATS] {
    crate::simulator::analysis::static_bits(statics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    #[test]
    fn feature_bits_are_exact_counts() {
        let bits = static_feature_bits(&[1e9, 8.0, 3.0, 1.0, 2.0, 6.0, 0.0, 0.0, 0.0]);
        assert_eq!(bits, [1_000_000_000, 8, 3, 1, 2, 6, 0, 0, 0]);
        // Negative (impossible, but defensive) clamps to zero.
        assert_eq!(static_feature_bits(&[-1.0; STATIC_FEATS])[0], 0);
    }

    #[test]
    fn counts_and_macs() {
        let mut b = GraphBuilder::new("t", "t", 8);
        let x = b.input(vec![8, 3, 32, 32]);
        let c1 = b.conv_relu(x, 16, 3, 1, 1);
        let c2 = b.conv_relu(c1, 16, 3, 1, 1);
        let p = b.add(crate::ir::OpKind::GlobalAvgPool2d, crate::ir::Attrs::none(), &[c2]);
        let f = b.add(crate::ir::OpKind::Flatten, crate::ir::Attrs::none(), &[p]);
        b.dense(f, 10);
        let g = b.finish();
        let s = static_features(&g);
        assert!(s[0] > 0.0); // MACs
        assert_eq!(s[1], 8.0); // batch
        assert_eq!(s[2], 2.0); // convs
        assert_eq!(s[3], 1.0); // dense
        assert_eq!(s[4], 2.0); // relus
        assert_eq!(s[5], g.nodes.len() as f64); // all nodes fp32
        assert_eq!(&s[6..], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn dtype_counts_track_quantization() {
        let mut b = GraphBuilder::new("t", "t", 1);
        let x = b.input(vec![1, 3, 8, 8]);
        b.conv2d(x, 4, 3, 1, 1);
        let g = b.finish();
        let q = crate::ir::quantize::quantize(&g, crate::ir::DType::I8);
        let s = static_features(&q);
        assert_eq!(s[5], 0.0);
        assert_eq!(s[8], q.nodes.len() as f64);
        // eq.-1 prefix unchanged by quantization
        assert_eq!(&static_features(&g)[..EQ1_STATIC_FEATS], &s[..EQ1_STATIC_FEATS]);
    }

    #[test]
    fn batch_scales_macs_linearly() {
        let build = |batch| {
            let mut b = GraphBuilder::new("t", "t", batch);
            let x = b.input(vec![batch, 3, 32, 32]);
            b.conv2d(x, 16, 3, 1, 1);
            b.finish()
        };
        let s1 = static_features(&build(1));
        let s4 = static_features(&build(4));
        assert!((s4[0] / s1[0] - 4.0).abs() < 1e-9);
    }
}
