//! Featurization: the Node Feature Generator (paper §3.2, Algorithm 1) and
//! the Static Feature Generator (paper §3.3, eq. 1).
//!
//! The NFG walks the IR in post-order, emits a fixed 36-feature vector per
//! operator node (one-hot category ⊕ attributes ⊕ output shape ⊕ dtype
//! one-hot) and the row-normalized adjacency-with-self-loops Â the dense
//! GraphSAGE kernel consumes. The SFG emits
//! `F_s = MACs ⊕ batch ⊕ #conv ⊕ #dense ⊕ #relu ⊕ dtype counts`.

pub mod node_features;
pub mod static_features;

pub use node_features::{
    encode_graph, encode_graph_analyzed, fill_padded, fill_padded_analyzed, FeatureConfig,
    GraphFeatures, NODE_FEATS,
};
pub use static_features::{static_feature_bits, static_features, EQ1_STATIC_FEATS, STATIC_FEATS};
