//! Node Feature Generator — paper Algorithm 1.
//!
//! For each operator node: `F_node = one_hot(op) ⊕ F_attr ⊕ F_shape ⊕
//! one_hot(dtype)`, fixed length 36 — the paper's 32 (18 one-hot categories
//! + 6 attribute features + 8 shape features, §3.2) extended with a 4-wide
//! dtype one-hot (fp32/fp16/bf16/int8) so the predictor sees quantization.
//! All features are scaled to roughly [0, 1] with log transforms on
//! magnitudes so the GNN sees well-conditioned inputs.
//!
//! The adjacency matrix Â is row-normalized with self-loops — the mean
//! aggregator of the GraphSAGE layer folded into the matrix (DESIGN.md §7),
//! emitted in the dense padded layout the AOT kernels are specialized to.

use crate::ir::infer::numel;
use crate::ir::op::N_CATEGORIES;
use crate::ir::Graph;
use crate::simulator::cost::{op_cost, OpCost};
use crate::simulator::GraphAnalysis;

/// Number of attribute features.
pub const ATTR_FEATS: usize = 6;
/// Number of output-shape features.
pub const SHAPE_FEATS: usize = 8;
/// Width of the dtype one-hot block.
pub const DTYPE_FEATS: usize = crate::ir::ALL_DTYPES.len();
/// Total node feature length — the paper's fixed 32 (§3.2) plus the dtype
/// one-hot block.
pub const NODE_FEATS: usize = N_CATEGORIES + ATTR_FEATS + SHAPE_FEATS + DTYPE_FEATS;

/// Shape configuration of the padded encoding (mirrors the AOT manifest).
#[derive(Debug, Clone, Copy)]
pub struct FeatureConfig {
    pub max_nodes: usize,
    pub node_feats: usize,
}

impl FeatureConfig {
    pub fn new(max_nodes: usize) -> FeatureConfig {
        FeatureConfig {
            max_nodes,
            node_feats: NODE_FEATS,
        }
    }
}

/// Dense featurized graph: X [n, F] row-major, Â [n, n] row-major, n nodes.
#[derive(Debug, Clone)]
pub struct GraphFeatures {
    pub n: usize,
    pub x: Vec<f32>,
    pub a_hat: Vec<f32>,
}

/// Encode one node's features into `out`, computing the node's cost
/// from scratch (legacy path; the serving path passes cached costs via
/// [`node_feature_row_with_cost`]).
fn node_feature_row(graph: &Graph, id: usize, out: &mut [f32]) {
    node_feature_row_with_cost(graph, id, &op_cost(graph, &graph.nodes[id]), out)
}

/// Encode one node's features into `out` from a precomputed [`OpCost`].
fn node_feature_row_with_cost(graph: &Graph, id: usize, cost: &OpCost, out: &mut [f32]) {
    debug_assert_eq!(out.len(), NODE_FEATS);
    let node = &graph.nodes[id];
    out.fill(0.0);

    // --- one-hot operator category (paper line 6: one_hot_encoder) ------
    out[node.op.category()] = 1.0;

    // --- attribute features (line 7: ExtractAttributes) -----------------
    let a = &node.attrs;
    let base = N_CATEGORIES;
    let (kh, kw) = a.kernel.unwrap_or((0, 0));
    out[base] = kh as f32 / 11.0;
    out[base + 1] = kw as f32 / 11.0;
    let (sh, _) = a.strides.unwrap_or((0, 0));
    out[base + 2] = sh as f32 / 4.0;
    out[base + 3] = a.padding as f32 / 5.0;
    out[base + 4] = ((a.groups.max(1)) as f32).log2() / 10.0;
    out[base + 5] = a.axis.unwrap_or(0) as f32 / 4.0;

    // --- output-shape features (line 8: ExtractOutshape) ----------------
    let s = &node.out_shape;
    let base = N_CATEGORIES + ATTR_FEATS;
    for d in 0..4 {
        let v = s.get(d).copied().unwrap_or(0) as f32;
        out[base + d] = (v + 1.0).ln() / 8.0;
    }
    out[base + 4] = s.len() as f32 / 4.0;
    out[base + 5] = (numel(s) as f32 + 1.0).ln() / 18.0;
    out[base + 6] = ((cost.flops + 1.0) as f32).ln() / 26.0;
    out[base + 7] = ((cost.total_bytes() + 1.0) as f32).ln() / 22.0;

    // --- dtype one-hot ---------------------------------------------------
    let base = N_CATEGORIES + ATTR_FEATS + SHAPE_FEATS;
    out[base + a.dtype.index()] = 1.0;
}

/// Encode the whole graph (Algorithm 1's CreateGraph): X and Â at natural
/// (unpadded) size, nodes in the IR's topological order — the same order
/// the post-order filter yields up to relabeling, and the order the padded
/// batch uses.
pub fn encode_graph(graph: &Graph) -> GraphFeatures {
    encode_graph_impl(graph, node_feature_row)
}

/// [`encode_graph`] from a precomputed analysis: node cost features come
/// from the cached per-node [`OpCost`]s — no cost recomputation.
pub fn encode_graph_analyzed(graph: &Graph, analysis: &GraphAnalysis) -> GraphFeatures {
    debug_assert_eq!(analysis.n_nodes, graph.n_nodes());
    encode_graph_impl(graph, |graph, id, out| {
        node_feature_row_with_cost(graph, id, &analysis.costs[id], out)
    })
}

fn encode_graph_impl(graph: &Graph, row: impl Fn(&Graph, usize, &mut [f32])) -> GraphFeatures {
    let n = graph.n_nodes();
    let mut x = vec![0.0f32; n * NODE_FEATS];
    for id in 0..n {
        row(graph, id, &mut x[id * NODE_FEATS..(id + 1) * NODE_FEATS]);
    }

    // Â: adjacency with self-loops, row-normalized (mean aggregation).
    let mut a_hat = vec![0.0f32; n * n];
    for node in &graph.nodes {
        let i = node.id;
        a_hat[i * n + i] = 1.0;
        for &src in &node.inputs {
            a_hat[i * n + src] = 1.0;
        }
    }
    for i in 0..n {
        let row = &mut a_hat[i * n..(i + 1) * n];
        let deg: f32 = row.iter().sum();
        if deg > 0.0 {
            for v in row.iter_mut() {
                *v /= deg;
            }
        }
    }
    GraphFeatures { n, x, a_hat }
}

/// Fill one padded sample into caller-provided buffers (the training/serving
/// batch assemblers call this directly into their pinned batch buffers —
/// the serving hot path allocates nothing).
///
/// `x_out` is [max_nodes * node_feats], `a_out` [max_nodes²], `mask_out`
/// [max_nodes]. Returns Err if the graph exceeds `max_nodes`.
pub fn fill_padded(
    graph: &Graph,
    cfg: FeatureConfig,
    x_out: &mut [f32],
    a_out: &mut [f32],
    mask_out: &mut [f32],
) -> Result<(), String> {
    fill_padded_impl(graph, cfg, x_out, a_out, mask_out, node_feature_row)
}

/// [`fill_padded`] from a precomputed analysis: the serving batch
/// assembler's path — cached per-node costs, zero graph re-traversal.
pub fn fill_padded_analyzed(
    graph: &Graph,
    analysis: &GraphAnalysis,
    cfg: FeatureConfig,
    x_out: &mut [f32],
    a_out: &mut [f32],
    mask_out: &mut [f32],
) -> Result<(), String> {
    debug_assert_eq!(analysis.n_nodes, graph.n_nodes());
    fill_padded_impl(graph, cfg, x_out, a_out, mask_out, |graph, id, out| {
        node_feature_row_with_cost(graph, id, &analysis.costs[id], out)
    })
}

fn fill_padded_impl(
    graph: &Graph,
    cfg: FeatureConfig,
    x_out: &mut [f32],
    a_out: &mut [f32],
    mask_out: &mut [f32],
    row: impl Fn(&Graph, usize, &mut [f32]),
) -> Result<(), String> {
    let n = graph.n_nodes();
    let m = cfg.max_nodes;
    if n > m {
        return Err(format!(
            "graph {} has {n} nodes > max_nodes {m}",
            graph.variant
        ));
    }
    assert_eq!(cfg.node_feats, NODE_FEATS, "manifest/feature length mismatch");
    assert_eq!(x_out.len(), m * cfg.node_feats);
    assert_eq!(a_out.len(), m * m);
    assert_eq!(mask_out.len(), m);

    x_out.fill(0.0);
    a_out.fill(0.0);
    mask_out.fill(0.0);

    for id in 0..n {
        row(
            graph,
            id,
            &mut x_out[id * cfg.node_feats..(id + 1) * cfg.node_feats],
        );
        mask_out[id] = 1.0;
    }
    // Row-normalized adjacency with self-loops, directly in padded layout.
    for node in &graph.nodes {
        let i = node.id;
        a_out[i * m + i] = 1.0;
        for &src in &node.inputs {
            a_out[i * m + src] = 1.0;
        }
    }
    for i in 0..n {
        let row = &mut a_out[i * m..i * m + n];
        let deg: f32 = row.iter().sum();
        if deg > 0.0 {
            for v in row.iter_mut() {
                *v /= deg;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Attrs, GraphBuilder, OpKind};

    fn tiny() -> Graph {
        let mut b = GraphBuilder::new("t", "tiny", 2);
        let x = b.input(vec![2, 3, 16, 16]);
        let c = b.conv_relu(x, 8, 3, 2, 1);
        let p = b.add(OpKind::GlobalAvgPool2d, Attrs::none(), &[c]);
        let f = b.add(OpKind::Flatten, Attrs::none(), &[p]);
        b.dense(f, 10);
        b.finish()
    }

    #[test]
    fn feature_length_is_36() {
        // the paper's fixed 32 (§3.2) + the 4-wide dtype one-hot
        assert_eq!(NODE_FEATS, 36);
    }

    #[test]
    fn dtype_one_hot_encoded() {
        use crate::ir::DType;
        let g = tiny();
        let f = encode_graph(&g);
        let base = N_CATEGORIES + ATTR_FEATS + SHAPE_FEATS;
        for i in 0..f.n {
            let row = &f.x[i * NODE_FEATS..(i + 1) * NODE_FEATS];
            assert_eq!(row[base], 1.0, "node {i} must be fp32");
            assert!(row[base + 1..].iter().all(|&v| v == 0.0));
        }
        let q = crate::ir::quantize::quantize(&g, DType::I8);
        let fq = encode_graph(&q);
        for i in 0..fq.n {
            let row = &fq.x[i * NODE_FEATS..(i + 1) * NODE_FEATS];
            assert_eq!(row[base + DType::I8.index()], 1.0, "node {i}");
            assert_eq!(row[base], 0.0);
        }
        // all non-dtype features except the byte-derived ones match
        for i in 0..f.n {
            let a = &f.x[i * NODE_FEATS..i * NODE_FEATS + N_CATEGORIES + ATTR_FEATS];
            let b = &fq.x[i * NODE_FEATS..i * NODE_FEATS + N_CATEGORIES + ATTR_FEATS];
            assert_eq!(a, b, "node {i}");
        }
    }

    #[test]
    fn one_hot_is_exclusive() {
        let g = tiny();
        let f = encode_graph(&g);
        for i in 0..f.n {
            let row = &f.x[i * NODE_FEATS..i * NODE_FEATS + N_CATEGORIES];
            let ones = row.iter().filter(|&&v| v == 1.0).count();
            assert_eq!(ones, 1, "node {i}");
        }
    }

    #[test]
    fn rows_of_a_hat_sum_to_one() {
        let g = tiny();
        let f = encode_graph(&g);
        for i in 0..f.n {
            let s: f32 = f.a_hat[i * f.n..(i + 1) * f.n].iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {i} sums to {s}");
        }
    }

    #[test]
    fn self_loops_present() {
        let g = tiny();
        let f = encode_graph(&g);
        for i in 0..f.n {
            assert!(f.a_hat[i * f.n + i] > 0.0);
        }
    }

    #[test]
    fn features_bounded() {
        let g = tiny();
        let f = encode_graph(&g);
        for (i, &v) in f.x.iter().enumerate() {
            assert!(v.is_finite() && (-1.5..=2.0).contains(&v), "x[{i}] = {v}");
        }
    }

    #[test]
    fn conv_attrs_encoded() {
        let g = tiny();
        let f = encode_graph(&g);
        // node 1 is the conv: kernel 3x3, stride 2.
        let row = &f.x[NODE_FEATS..2 * NODE_FEATS];
        assert!((row[N_CATEGORIES] - 3.0 / 11.0).abs() < 1e-6);
        assert!((row[N_CATEGORIES + 2] - 2.0 / 4.0).abs() < 1e-6);
    }

    #[test]
    fn encoding_is_deterministic() {
        let g = tiny();
        let f1 = encode_graph(&g);
        let f2 = encode_graph(&g);
        assert_eq!(f1.x, f2.x);
        assert_eq!(f1.a_hat, f2.a_hat);
    }

    #[test]
    fn fill_padded_matches_unpadded() {
        let g = tiny();
        let cfg = FeatureConfig::new(10);
        let mut x = vec![9.0; 10 * NODE_FEATS];
        let mut a = vec![9.0; 100];
        let mut mask = vec![9.0; 10];
        fill_padded(&g, cfg, &mut x, &mut a, &mut mask).unwrap();
        let f = encode_graph(&g);
        let n = f.n;
        for i in 0..n {
            assert_eq!(
                &x[i * NODE_FEATS..(i + 1) * NODE_FEATS],
                &f.x[i * NODE_FEATS..(i + 1) * NODE_FEATS]
            );
            for j in 0..n {
                assert_eq!(a[i * 10 + j], f.a_hat[i * n + j]);
            }
        }
        assert_eq!(&mask[..n], &vec![1.0; n][..]);
        assert_eq!(&mask[n..], &vec![0.0; 10 - n][..]);
        // Padding region zeroed.
        assert!(x[n * NODE_FEATS..].iter().all(|&v| v == 0.0));
        assert!(a[n * 10..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn analyzed_featurization_matches_scratch() {
        let g = tiny();
        let a = GraphAnalysis::of(&g);
        let scratch = encode_graph(&g);
        let analyzed = encode_graph_analyzed(&g, &a);
        assert_eq!(scratch.x, analyzed.x);
        assert_eq!(scratch.a_hat, analyzed.a_hat);

        let cfg = FeatureConfig::new(10);
        let (mut x1, mut a1, mut m1) = (vec![0.0; 10 * NODE_FEATS], vec![0.0; 100], vec![0.0; 10]);
        let (mut x2, mut a2, mut m2) = (vec![0.0; 10 * NODE_FEATS], vec![0.0; 100], vec![0.0; 10]);
        fill_padded(&g, cfg, &mut x1, &mut a1, &mut m1).unwrap();
        fill_padded_analyzed(&g, &a, cfg, &mut x2, &mut a2, &mut m2).unwrap();
        assert_eq!(x1, x2);
        assert_eq!(a1, a2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn fill_padded_rejects_oversize() {
        let g = tiny();
        let cfg = FeatureConfig::new(3);
        let mut x = vec![0.0; 3 * NODE_FEATS];
        let mut a = vec![0.0; 9];
        let mut mask = vec![0.0; 3];
        assert!(fill_padded(&g, cfg, &mut x, &mut a, &mut mask).is_err());
    }

    #[test]
    fn different_graphs_different_features() {
        let g1 = tiny();
        let mut b = GraphBuilder::new("t", "other", 2);
        let x = b.input(vec![2, 3, 16, 16]);
        b.conv_relu(x, 16, 5, 1, 2);
        let g2 = b.finish();
        let f1 = encode_graph(&g1);
        let f2 = encode_graph(&g2);
        assert_ne!(f1.x[..2 * NODE_FEATS], f2.x[..2 * NODE_FEATS]);
    }
}
