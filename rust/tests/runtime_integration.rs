//! Integration: the AOT artifacts (python/jax/pallas) load and execute
//! correctly through the Rust PJRT runtime. Requires `make artifacts` and
//! the real xla bindings; every test self-skips when either is missing
//! (the offline vendor stub cannot execute artifacts).

use dippm::features::static_features;
use dippm::modelgen::Family;
use dippm::runtime::tensor::HostTensor;
use dippm::runtime::Runtime;
use dippm::training::BatchBuffers;

fn runtime() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: PJRT/artifacts unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn manifest_constants_match_feature_generator() {
    let Some(rt) = runtime() else { return };
    let c = rt.manifest.constants;
    assert_eq!(c.node_feats, dippm::features::node_features::NODE_FEATS);
    assert_eq!(c.static_feats, dippm::features::STATIC_FEATS);
    assert_eq!(c.targets, 3);
    assert!(c.max_nodes >= 128);
}

#[test]
fn init_params_match_manifest_shapes() {
    let Some(rt) = runtime() else { return };
    for variant in ["sage", "gcn", "gin", "gat", "mlp"] {
        let params = rt.init_params(variant, 0).unwrap();
        let info = rt.variant(variant).unwrap();
        assert_eq!(params.tensors.len(), info.n_params(), "{variant}");
        for ((name, shape), t) in info.params.iter().zip(&params.tensors) {
            assert_eq!(&t.shape, shape, "{variant}/{name}");
            assert!(t.data.iter().all(|v| v.is_finite()), "{variant}/{name}");
        }
    }
}

#[test]
fn init_is_seed_deterministic_across_calls() {
    let Some(rt) = runtime() else { return };
    let a = rt.init_params("sage", 7).unwrap();
    let b = rt.init_params("sage", 7).unwrap();
    let c = rt.init_params("sage", 8).unwrap();
    for (x, y) in a.tensors.iter().zip(&b.tensors) {
        assert_eq!(x.data, y.data);
    }
    assert!(a
        .tensors
        .iter()
        .zip(&c.tensors)
        .any(|(x, y)| x.data != y.data));
}

#[test]
fn predict_b1_runs_on_generated_graph() {
    let Some(rt) = runtime() else { return };
    let c = rt.manifest.constants;
    let params = rt.init_params("sage", 0).unwrap();
    let graph = Family::ResNet.generate(0);
    let statics = static_features(&graph);
    let norm = dippm::dataset::NormStats::default();
    let mut bufs = BatchBuffers::new(&c, 1);
    bufs.fill_graph(&graph, &statics, &norm, 0).unwrap();
    let info = rt.variant("sage").unwrap().clone();
    let art = rt.artifact(info.predict_for(1).unwrap()).unwrap();
    let mut inputs = params.to_literals().unwrap();
    inputs.extend(bufs.feature_literals().unwrap());
    let outs = art.run(&inputs).unwrap();
    assert_eq!(outs.len(), 1);
    let y = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(y.len(), 3);
    assert!(y.iter().all(|v| v.is_finite()), "{y:?}");
}

#[test]
fn predict_is_deterministic_and_padding_invariant() {
    let Some(rt) = runtime() else { return };
    let c = rt.manifest.constants;
    let params = rt.init_params("sage", 3).unwrap();
    let graph = Family::Vgg.generate(1);
    let statics = static_features(&graph);
    let norm = dippm::dataset::NormStats::default();
    let info = rt.variant("sage").unwrap().clone();
    let art = rt.artifact(info.predict_for(1).unwrap()).unwrap();

    let run = |bufs: &BatchBuffers| -> Vec<f32> {
        let mut inputs = params.to_literals().unwrap();
        inputs.extend(bufs.feature_literals().unwrap());
        art.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap()
    };
    let mut bufs = BatchBuffers::new(&c, 1);
    bufs.fill_graph(&graph, &statics, &norm, 0).unwrap();
    let y1 = run(&bufs);
    let y2 = run(&bufs);
    assert_eq!(y1, y2, "predict must be deterministic (no dropout at eval)");

    // Poison the padding region of X beyond the mask: prediction unchanged.
    let n_nodes = graph.n_nodes();
    let f = c.node_feats;
    for i in n_nodes * f..c.max_nodes * f {
        bufs.x.data[i] = 42.0;
    }
    let y3 = run(&bufs);
    for (a, b) in y1.iter().zip(&y3) {
        assert!((a - b).abs() < 1e-4, "padding leaked into prediction");
    }
}

#[test]
fn batched_predict_matches_b1() {
    let Some(rt) = runtime() else { return };
    let c = rt.manifest.constants;
    let params = rt.init_params("sage", 5).unwrap();
    let norm = dippm::dataset::NormStats::default();
    let info = rt.variant("sage").unwrap().clone();
    let art1 = rt.artifact(info.predict_for(1).unwrap()).unwrap();
    let artb = rt.artifact(info.predict_for(c.batch).unwrap()).unwrap();

    let graphs: Vec<_> = (0..4).map(|i| Family::MobileNet.generate(i)).collect();
    // Batched run.
    let mut bb = BatchBuffers::new(&c, c.batch);
    for (slot, g) in graphs.iter().enumerate() {
        bb.fill_graph(g, &static_features(g), &norm, slot).unwrap();
    }
    for slot in graphs.len()..c.batch {
        bb.clear_slot(slot);
    }
    let mut inputs = params.to_literals().unwrap();
    inputs.extend(bb.feature_literals().unwrap());
    let yb = artb.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap();
    // Individual runs must agree with the batched slots.
    for (slot, g) in graphs.iter().enumerate() {
        let mut b1 = BatchBuffers::new(&c, 1);
        b1.fill_graph(g, &static_features(g), &norm, 0).unwrap();
        let mut inputs = params.to_literals().unwrap();
        inputs.extend(b1.feature_literals().unwrap());
        let y1 = art1.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap();
        for d in 0..3 {
            assert!(
                (y1[d] - yb[slot * 3 + d]).abs() < 1e-3,
                "slot {slot} dim {d}: {} vs {}",
                y1[d],
                yb[slot * 3 + d]
            );
        }
    }
}

#[test]
fn literal_roundtrip() {
    let Some(_rt) = runtime() else { return }; // ensures the PJRT lib is loaded
    let t = HostTensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]);
    let lit = t.to_literal().unwrap();
    let back = HostTensor::from_literal(&lit).unwrap();
    assert_eq!(t, back);
}

#[test]
fn artifact_cache_reuses_compilation() {
    let Some(rt) = runtime() else { return };
    let info = rt.variant("mlp").unwrap().clone();
    let a1 = rt.artifact(&info.init).unwrap();
    let a2 = rt.artifact(&info.init).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a1, &a2));
}
