//! Crash-safety harness for the cache journal store (`cache::persist`):
//!
//! * **Kill-at-every-injection-point** — a crash hook kills persistence at
//!   every labeled point (mid-append, torn record, mid-compaction,
//!   mid-manifest-swap, post-commit-pre-cleanup) and recovery must be
//!   bit-identical to the committed pre-crash state: torn tails truncated
//!   (never a cold start), corrupt manifests falling back one generation.
//! * **Property round-trips** — random op sequences: journal replay must
//!   equal an in-memory model, and a store that compacts aggressively must
//!   recover the same state as one that never compacts.
//! * **Fuzzed corruption** — random byte flips / truncations of journal
//!   files must recover a clean *prefix* of the op stream (and a corrupted
//!   manifest must recover everything via fallback), never panic or error.
//!
//! Set `DIPPM_JOURNAL_TEST_DIR` to root the store directories somewhere
//! persistent (the CI `persist-crash` job points it at the workspace and
//! uploads the directories on failure); cleanup happens only on success.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::Result;
use dippm::cache::persist::{
    read_store, BootLoad, Delta, DeltaKind, JournalStore, PersistConfig, SnapshotValue,
    CRASH_POINTS,
};
use dippm::util::proptest::proptest;
use dippm::{prop_assert, prop_assert_eq};

/// Minimal journaled value for the harness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct TVal(u32);

impl SnapshotValue for TVal {
    fn snapshot_encode(&self) -> Option<Vec<u8>> {
        Some(self.0.to_le_bytes().to_vec())
    }
    fn snapshot_decode(bytes: &[u8]) -> Result<TVal> {
        let arr: [u8; 4] = bytes
            .try_into()
            .map_err(|_| anyhow::anyhow!("TVal payload must be 4 bytes"))?;
        Ok(TVal(u32::from_le_bytes(arr)))
    }
}

static DIR_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Fresh store directory under `DIPPM_JOURNAL_TEST_DIR` (CI artifact root)
/// or the system temp dir.
fn store_dir(name: &str) -> PathBuf {
    let root = std::env::var("DIPPM_JOURNAL_TEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| std::env::temp_dir());
    let dir = root.join(format!(
        "dippm-journal-{}-{name}-{}",
        std::process::id(),
        DIR_COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cleanup(dir: &PathBuf) {
    let _ = std::fs::remove_dir_all(dir);
}

fn cfg(dir: &PathBuf, shards: usize) -> PersistConfig {
    PersistConfig {
        shards,
        ..PersistConfig::at(dir.clone())
    }
}

/// Key whose high bits place it on shard `i % shards`.
fn key(i: u64) -> u128 {
    ((i as u128) << 64) | i as u128
}

fn upsert(i: u64, v: u32) -> Delta<TVal> {
    Delta {
        key: key(i),
        kind: DeltaKind::Upsert(TVal(v), Duration::ZERO),
    }
}

fn remove(i: u64) -> Delta<TVal> {
    Delta {
        key: key(i),
        kind: DeltaKind::Remove,
    }
}

/// Fold a recovered boot load into its logical key→value state.
fn fold(boot: &BootLoad<TVal>) -> BTreeMap<u128, u32> {
    let mut m = BTreeMap::new();
    for (k, v, _) in &boot.base {
        m.insert(*k, v.0);
    }
    apply_deltas(&mut m, &boot.replay);
    m
}

fn apply_deltas(m: &mut BTreeMap<u128, u32>, deltas: &[Delta<TVal>]) {
    for d in deltas {
        match &d.kind {
            DeltaKind::Upsert(v, _) => {
                m.insert(d.key, v.0);
            }
            DeltaKind::Remove => {
                m.remove(&d.key);
            }
        }
    }
}

fn state(pairs: &[(u64, u32)]) -> BTreeMap<u128, u32> {
    pairs.iter().map(|&(k, v)| (key(k), v)).collect()
}

const APPEND_POINTS: &[&str] = &["append:start", "append:torn-record", "append:after-write"];
const COMPACT_POINTS: &[&str] = &[
    "compact:start",
    "compact:mid-shard",
    "compact:after-gen-write",
    "compact:mid-manifest-swap",
    "compact:after-manifest",
];

#[test]
fn harness_covers_every_labeled_crash_point() {
    assert_eq!(
        CRASH_POINTS.len(),
        APPEND_POINTS.len() + COMPACT_POINTS.len(),
        "a new crash point was added without harness coverage"
    );
    for p in APPEND_POINTS.iter().chain(COMPACT_POINTS) {
        assert!(CRASH_POINTS.contains(p), "unknown point {p}");
    }
}

#[test]
fn kill_at_every_append_point_recovers_committed_state() {
    for &point in APPEND_POINTS {
        let dir = store_dir("kill-append");
        let (store, _) = JournalStore::<TVal>::open(&cfg(&dir, 4)).unwrap();
        // Committed prefix: two acknowledged flushes.
        store.append(vec![upsert(1, 10)]).unwrap();
        store.append(vec![upsert(2, 20), remove(1), upsert(5, 50)]).unwrap();
        let committed = state(&[(2, 20), (5, 50)]);

        // The crashing flush: a single-record batch so the torn-record
        // point has a deterministic durable/dropped outcome.
        store.set_crash_hook(Some(Box::new(move |p| p == point)));
        let err = store.append(vec![upsert(3, 30)]).unwrap_err();
        assert!(format!("{err:#}").contains("injected crash"), "{point}: {err:#}");
        // The store is poisoned, exactly like a dead process.
        assert!(store.append(vec![upsert(4, 44)]).is_err(), "{point}");
        drop(store);

        // Recovery: reopen the directory cold.
        let (_store, boot) = JournalStore::<TVal>::open(&cfg(&dir, 4)).unwrap();
        let mut expected = committed.clone();
        match point {
            // Nothing of the crashed record reached the disk.
            "append:start" => {}
            // Half a record on disk: recovery truncates the torn tail.
            "append:torn-record" => {
                assert_eq!(boot.report.torn_tail_drops, 1, "{point}");
            }
            // The record is durable; only the ack was lost.
            "append:after-write" => {
                expected.insert(key(3), 30);
            }
            other => unreachable!("unhandled append point {other}"),
        }
        assert_eq!(fold(&boot), expected, "recovery mismatch at {point}");
        assert!(!fold(&boot).is_empty(), "{point}: must never cold-start");

        // The recovered store keeps working (the torn tail was repaired).
        let (store, _) = JournalStore::<TVal>::open(&cfg(&dir, 4)).unwrap();
        store.append(vec![upsert(9, 90)]).unwrap();
        drop(store);
        let (_s, boot) = JournalStore::<TVal>::open(&cfg(&dir, 4)).unwrap();
        assert_eq!(fold(&boot).get(&key(9)), Some(&90), "{point}");
        cleanup(&dir);
    }
}

#[test]
fn kill_at_every_compaction_point_preserves_state_exactly() {
    for &point in COMPACT_POINTS {
        let dir = store_dir("kill-compact");
        let (store, _) = JournalStore::<TVal>::open(&cfg(&dir, 4)).unwrap();
        // Committed state via journal appends across several shards
        // (shard 0 must be non-empty for the mid-shard injection).
        store
            .append(vec![upsert(4, 40), upsert(5, 50), upsert(6, 60), remove(6)])
            .unwrap();
        let committed = state(&[(4, 40), (5, 50)]);
        let export: Vec<(u128, TVal, Duration)> = committed
            .iter()
            .map(|(&k, &v)| (k, TVal(v), Duration::ZERO))
            .collect();

        store.set_crash_hook(Some(Box::new(move |p| p == point)));
        let err = store.compact(export, 4).unwrap_err();
        assert!(format!("{err:#}").contains("injected crash"), "{point}: {err:#}");
        drop(store);

        // A crashed compaction — at ANY point — must leave the committed
        // state bit-identical: either the old generation (manifest never
        // landed, or fell back via MANIFEST.prev) or the new one (manifest
        // landed; base == the same logical state).
        let (_store, boot) = JournalStore::<TVal>::open(&cfg(&dir, 4)).unwrap();
        assert_eq!(fold(&boot), committed, "recovery mismatch at {point}");
        if point == "compact:mid-manifest-swap" {
            assert!(
                boot.report.recovered_previous_manifest,
                "mid-swap crash must recover via MANIFEST.prev"
            );
        }
        assert!(!fold(&boot).is_empty(), "{point}: must never cold-start");

        // And the recovered store can compact successfully afterwards.
        let (store, boot) = JournalStore::<TVal>::open(&cfg(&dir, 4)).unwrap();
        let export: Vec<(u128, TVal, Duration)> = fold(&boot)
            .iter()
            .map(|(&k, &v)| (k, TVal(v), Duration::ZERO))
            .collect();
        store.compact(export, 2).unwrap();
        drop(store);
        let (_s, boot) = JournalStore::<TVal>::open(&cfg(&dir, 4)).unwrap();
        assert_eq!(fold(&boot), committed, "post-recovery compaction at {point}");
        cleanup(&dir);
    }
}

/// One random op: `(key index, None = remove / Some(value) = upsert)`.
type Op = (u64, Option<u32>);

fn gen_ops(g: &mut dippm::util::proptest::Gen, max_len: usize) -> Vec<Op> {
    let n = g.usize_in(1, max_len);
    (0..n)
        .map(|_| {
            let k = g.usize_in(0, 9) as u64;
            if g.bool() {
                (k, None)
            } else {
                (k, Some(g.usize_in(0, 1_000_000) as u32))
            }
        })
        .collect()
}

fn op_delta(op: Op) -> Delta<TVal> {
    match op.1 {
        Some(v) => upsert(op.0, v),
        None => remove(op.0),
    }
}

#[test]
fn prop_journal_replay_equals_in_memory_model() {
    proptest(40, |g| {
        let dir = store_dir("prop-replay");
        let (store, _) = JournalStore::<TVal>::open(&cfg(&dir, 4)).map_err(|e| e.to_string())?;
        let ops = gen_ops(g, 60);
        let mut model = BTreeMap::new();
        let mut batch = Vec::new();
        for &op in &ops {
            batch.push(op_delta(op));
            apply_deltas(&mut model, &[op_delta(op)]);
            // Random flush boundaries.
            if g.bool() {
                store.append(std::mem::take(&mut batch)).map_err(|e| e.to_string())?;
            }
        }
        store.append(batch).map_err(|e| e.to_string())?;
        drop(store);

        let (_s, boot) = JournalStore::<TVal>::open(&cfg(&dir, 4)).map_err(|e| e.to_string())?;
        prop_assert_eq!(fold(&boot), model);
        cleanup(&dir);
        Ok(())
    });
}

#[test]
fn prop_compaction_equals_no_compaction() {
    proptest(25, |g| {
        let dir_a = store_dir("prop-nocompact");
        let dir_b = store_dir("prop-compact");
        let (a, _) = JournalStore::<TVal>::open(&cfg(&dir_a, 4)).map_err(|e| e.to_string())?;
        let (b, _) = JournalStore::<TVal>::open(&cfg(&dir_b, 4)).map_err(|e| e.to_string())?;
        let ops = gen_ops(g, 50);
        let mut model: BTreeMap<u128, u32> = BTreeMap::new();
        let mut batch = Vec::new();
        for &op in &ops {
            batch.push(op_delta(op));
            apply_deltas(&mut model, &[op_delta(op)]);
            if g.bool() {
                let deltas: Vec<Delta<TVal>> = batch.drain(..).collect();
                a.append(deltas.clone()).map_err(|e| e.to_string())?;
                b.append(deltas).map_err(|e| e.to_string())?;
                // Store B compacts aggressively from the model state (what
                // the live cache would export at this moment).
                if g.bool() {
                    let export: Vec<(u128, TVal, Duration)> = model
                        .iter()
                        .map(|(&k, &v)| (k, TVal(v), Duration::ZERO))
                        .collect();
                    b.compact(export, 3).map_err(|e| e.to_string())?;
                }
            }
        }
        a.append(batch.clone()).map_err(|e| e.to_string())?;
        b.append(batch).map_err(|e| e.to_string())?;
        drop(a);
        drop(b);

        let (_sa, boot_a) =
            JournalStore::<TVal>::open(&cfg(&dir_a, 4)).map_err(|e| e.to_string())?;
        let (_sb, boot_b) =
            JournalStore::<TVal>::open(&cfg(&dir_b, 4)).map_err(|e| e.to_string())?;
        prop_assert_eq!(fold(&boot_a), fold(&boot_b));
        prop_assert_eq!(fold(&boot_a), model);
        cleanup(&dir_a);
        cleanup(&dir_b);
        Ok(())
    });
}

/// Every journal file of generation 1 in the dir (single-shard tests).
fn journal_files(dir: &PathBuf) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .map(|n| n.to_string_lossy().starts_with("journal-"))
                .unwrap_or(false)
        })
        .collect();
    out.sort();
    out
}

#[test]
fn prop_fuzzed_journal_corruption_recovers_a_clean_prefix() {
    proptest(30, |g| {
        // Single shard so the journal is one file and replay order is the
        // op order — recovery must then be the fold of some op *prefix*.
        let dir = store_dir("fuzz");
        let (store, _) = JournalStore::<TVal>::open(&cfg(&dir, 1)).map_err(|e| e.to_string())?;
        let ops = gen_ops(g, 30);
        for chunk in ops.chunks(5) {
            store
                .append(chunk.iter().map(|&op| op_delta(op)).collect())
                .map_err(|e| e.to_string())?;
        }
        drop(store);
        // All prefix folds of the op stream (the acceptable recoveries).
        let mut prefixes = vec![BTreeMap::new()];
        let mut acc = BTreeMap::new();
        for &op in &ops {
            apply_deltas(&mut acc, &[op_delta(op)]);
            prefixes.push(acc.clone());
        }

        let files = journal_files(&dir);
        prop_assert!(!files.is_empty(), "journal file must exist");
        let target = &files[0];
        let mut bytes = std::fs::read(target).map_err(|e| e.to_string())?;
        prop_assert!(!bytes.is_empty());
        if g.bool() {
            // Truncate at a random offset.
            let cut = g.usize_in(0, bytes.len() - 1);
            bytes.truncate(cut);
        } else {
            // Flip one random byte.
            let at = g.usize_in(0, bytes.len() - 1);
            bytes[at] ^= 1 << g.usize_in(0, 7);
        }
        std::fs::write(target, &bytes).map_err(|e| e.to_string())?;

        // Recovery must succeed and land exactly on a prefix fold.
        let (_s, boot) = JournalStore::<TVal>::open(&cfg(&dir, 1)).map_err(|e| e.to_string())?;
        let recovered = fold(&boot);
        prop_assert!(
            prefixes.contains(&recovered),
            "recovered state {recovered:?} is not a clean prefix of the op stream"
        );
        cleanup(&dir);
        Ok(())
    });
}

#[test]
fn prop_fuzzed_manifest_corruption_never_loses_journaled_state() {
    proptest(15, |g| {
        let dir = store_dir("fuzz-manifest");
        let (store, _) = JournalStore::<TVal>::open(&cfg(&dir, 2)).map_err(|e| e.to_string())?;
        let ops = gen_ops(g, 20);
        let mut model = BTreeMap::new();
        for &op in &ops {
            apply_deltas(&mut model, &[op_delta(op)]);
        }
        store
            .append(ops.iter().map(|&op| op_delta(op)).collect())
            .map_err(|e| e.to_string())?;
        drop(store);

        let manifest = dir.join("MANIFEST");
        let mut bytes = std::fs::read(&manifest).map_err(|e| e.to_string())?;
        let at = g.usize_in(0, bytes.len() - 1);
        bytes[at] ^= 0x40;
        std::fs::write(&manifest, &bytes).map_err(|e| e.to_string())?;

        // No compaction has run, so the journals carry everything: a
        // corrupt manifest (no .prev yet) must still recover the full
        // state by replaying the newest generation's journals.
        let (_s, boot) = JournalStore::<TVal>::open(&cfg(&dir, 2)).map_err(|e| e.to_string())?;
        prop_assert_eq!(fold(&boot), model);
        cleanup(&dir);
        Ok(())
    });
}

#[test]
fn read_store_round_trips_a_compacted_store() {
    let dir = store_dir("read-store");
    let (store, _) = JournalStore::<TVal>::open(&cfg(&dir, 4)).unwrap();
    store
        .append(vec![upsert(1, 1), upsert(2, 2), upsert(3, 3), remove(2)])
        .unwrap();
    let export: Vec<(u128, TVal, Duration)> = state(&[(1, 1), (3, 3)])
        .iter()
        .map(|(&k, &v)| (k, TVal(v), Duration::ZERO))
        .collect();
    store.compact(export, 2).unwrap();
    store.append(vec![upsert(4, 4)]).unwrap();
    drop(store);

    let boot = read_store::<TVal>(&dir).unwrap();
    assert_eq!(fold(&boot), state(&[(1, 1), (3, 3), (4, 4)]));
    cleanup(&dir);
}
