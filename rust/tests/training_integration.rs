//! Integration: the full training path — dataset → batches → PJRT train
//! step (Adam in HLO) → falling loss → MAPE eval → checkpoint round-trip.
//! Requires `make artifacts` + the real xla bindings; every test self-skips
//! when either is missing (the offline vendor stub cannot execute HLO).

use dippm::dataset::Dataset;
use dippm::runtime::{ParamStore, Runtime};
use dippm::training::{trainer, TrainConfig, Trainer};

fn runtime() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: PJRT/artifacts unavailable: {e:#}");
            None
        }
    }
}

fn tiny_dataset() -> Dataset {
    // ~105 samples: enough for a couple of batches per epoch.
    Dataset::build(0.01, 11, 4)
}

#[test]
fn loss_decreases_over_epochs() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mut t = Trainer::new(
        &rt,
        TrainConfig {
            epochs: 6,
            lr: 3e-3,
            ..Default::default()
        },
    )
    .unwrap();
    let mut logs = Vec::new();
    for e in 0..6 {
        logs.push(t.train_epoch(&ds, e).unwrap());
    }
    let first = logs.first().unwrap().mean_loss;
    let last = logs.last().unwrap().mean_loss;
    assert!(
        last < first * 0.8,
        "loss did not fall: {first:.4} -> {last:.4}"
    );
}

#[test]
fn training_improves_mape_and_checkpoint_roundtrips() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mut t = Trainer::new(
        &rt,
        TrainConfig {
            epochs: 10,
            lr: 3e-3,
            seed: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let before = t.evaluate(&ds, &ds.splits.val).unwrap();
    for e in 0..10 {
        t.train_epoch(&ds, e).unwrap();
    }
    let after = t.evaluate(&ds, &ds.splits.val).unwrap();
    assert!(
        after.overall() < before.overall(),
        "val MAPE did not improve: {:.3} -> {:.3}",
        before.overall(),
        after.overall()
    );
    assert!(after.n == ds.splits.val.len());
    assert!(after.pairs.iter().all(|(p, a)| p
        .iter()
        .chain(a.iter())
        .all(|v| v.is_finite())));

    // Checkpoint round-trip reproduces evaluation exactly.
    let path = std::env::temp_dir().join("dippm_train_it_ck.bin");
    let path = path.to_str().unwrap().to_string();
    t.params.save(&path).unwrap();
    let loaded = ParamStore::load(&path).unwrap();
    let again = trainer::evaluate_params(&rt, &loaded, &ds, &ds.splits.val).unwrap();
    assert!((again.overall() - after.overall()).abs() < 1e-9);
    std::fs::remove_file(path).ok();
}

#[test]
fn mse_ablation_artifact_trains() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mut t = Trainer::new(
        &rt,
        TrainConfig {
            epochs: 3,
            lr: 3e-3,
            mse_loss: true,
            ..Default::default()
        },
    )
    .unwrap();
    let logs: Vec<_> = (0..3).map(|e| t.train_epoch(&ds, e).unwrap()).collect();
    assert!(logs.last().unwrap().mean_loss < logs[0].mean_loss);
}

#[test]
fn all_variants_take_a_training_step() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    for variant in ["gcn", "gin", "gat", "mlp"] {
        let mut t = Trainer::new(
            &rt,
            TrainConfig {
                variant: variant.into(),
                epochs: 1,
                lr: 1e-3,
                max_train: Some(32),
                ..Default::default()
            },
        )
        .unwrap();
        let log = t.train_epoch(&ds, 0).unwrap();
        assert!(log.mean_loss.is_finite(), "{variant} loss NaN");
        assert!(log.steps >= 1, "{variant} took no steps");
    }
}

#[test]
fn lr_finder_produces_monotone_ramp() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mut t = Trainer::new(&rt, TrainConfig::default()).unwrap();
    let result = dippm::training::lr_finder::lr_find(&mut t, &ds, 1e-6, 1e-1, 12).unwrap();
    assert!(result.curve.len() >= 4);
    assert!(result.suggested > 0.0);
    // LRs strictly increase along the ramp.
    for w in result.curve.windows(2) {
        assert!(w[1].0 > w[0].0);
    }
}
