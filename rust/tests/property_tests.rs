//! Property-based tests over the Rust substrates (hermetic: no PJRT),
//! using the in-repo miniature proptest harness (util::proptest).

use dippm::cache::Fingerprint;
use dippm::dataset::split::Splits;
use dippm::features::{
    encode_graph, encode_graph_analyzed, fill_padded, fill_padded_analyzed, static_features,
    FeatureConfig,
};
use dippm::frontends::{self, Framework};
use dippm::ir::{Attrs, Graph, GraphBuilder, Node, NodeId, OpKind};
use dippm::modelgen::{Family, ALL_FAMILIES};
use dippm::simulator::cost::op_cost;
use dippm::simulator::{fusion, GraphAnalysis, MigProfile, Simulator, ALL_PROFILES};
use dippm::util::json::Json;
use dippm::util::proptest::{proptest, Gen};
use dippm::{prop_assert, prop_assert_eq};

/// Generate a random valid conv-net graph.
fn random_graph(g: &mut Gen) -> Graph {
    let batch = *g.rng.choose(&[1usize, 2, 4, 8, 16]);
    let res = *g.rng.choose(&[32usize, 64, 96]);
    let mut b = GraphBuilder::new("prop", &format!("rand-{}", g.rng.next_u32()), batch);
    let x = b.input(vec![batch, 3, res, res]);
    let mut h = b.conv_relu(x, 8 << g.rng.below(3), 3, 1, 1);
    let layers = g.usize_in(1, 8);
    let mut skip = h;
    for i in 0..layers {
        let ch = b.shape(h)[1];
        match g.rng.below(5) {
            0 => h = b.conv_relu(h, ch, 3, 1, 1),
            1 => h = b.depthwise(h, 3, 1, 1),
            2 => {
                if b.shape(skip) == b.shape(h) && skip != h {
                    h = b.add(OpKind::Add, Attrs::none(), &[h, skip]);
                } else {
                    h = b.relu(h);
                }
            }
            3 => h = b.add(OpKind::Concat, Attrs::with_axis(1), &[h, h]),
            _ => h = b.conv_relu(h, ch, 1, 1, 0),
        }
        if i == layers / 2 {
            skip = h;
        }
    }
    let p = b.add(OpKind::GlobalAvgPool2d, Attrs::none(), &[h]);
    let f = b.add(OpKind::Flatten, Attrs::none(), &[p]);
    b.dense(f, 10);
    b.finish()
}

/// Rebuild `graph` under a random topology-preserving relabeling: node ids
/// are permuted along a random topological order, every node is renamed,
/// and metadata is scrambled. The result is a *valid* Graph that is
/// isomorphic to the input.
fn relabel(graph: &Graph, g: &mut Gen) -> Graph {
    let n = graph.n_nodes();
    let consumers = graph.consumers();
    let mut remaining: Vec<usize> = graph.nodes.iter().map(|nd| nd.inputs.len()).collect();
    let mut ready: Vec<NodeId> = (0..n).filter(|&i| remaining[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while !ready.is_empty() {
        let k = g.rng.below(ready.len());
        let id = ready.swap_remove(k);
        order.push(id);
        for &c in &consumers[id] {
            remaining[c] -= 1;
            if remaining[c] == 0 {
                ready.push(c);
            }
        }
    }
    assert_eq!(order.len(), n, "input graph must be a DAG");
    let mut new_id = vec![0usize; n];
    for (pos, &old) in order.iter().enumerate() {
        new_id[old] = pos;
    }
    let nodes: Vec<Node> = order
        .iter()
        .map(|&old| {
            let src = &graph.nodes[old];
            Node {
                id: new_id[old],
                op: src.op,
                attrs: src.attrs.clone(),
                inputs: src.inputs.iter().map(|&i| new_id[i]).collect(),
                out_shape: src.out_shape.clone(),
                name: format!("perm_{}", g.rng.next_u32()),
            }
        })
        .collect();
    Graph {
        nodes,
        batch: graph.batch,
        family: "relabel".into(),
        variant: format!("perm-{}", g.rng.next_u32()),
    }
}

#[test]
fn fingerprint_invariant_under_relabeling_and_renaming() {
    proptest(60, |g| {
        let graph = random_graph(g);
        let permuted = relabel(&graph, g);
        prop_assert!(permuted.validate().is_ok(), "{:?}", permuted.validate());
        prop_assert_eq!(
            Fingerprint::of_graph(&graph),
            Fingerprint::of_graph(&permuted)
        );
        // Double relabeling too.
        let twice = relabel(&permuted, g);
        prop_assert_eq!(
            Fingerprint::of_graph(&graph),
            Fingerprint::of_graph(&twice)
        );
        Ok(())
    });
}

#[test]
fn fingerprint_detects_single_attribute_changes() {
    proptest(60, |g| {
        let graph = random_graph(g);
        let base = Fingerprint::of_graph(&graph);
        // Perturb one attribute of one random non-input node.
        let mut tweaked = graph.clone();
        let candidates: Vec<usize> = (0..tweaked.n_nodes())
            .filter(|&i| tweaked.nodes[i].op != OpKind::Input)
            .collect();
        let idx = *g.rng.choose(&candidates);
        match g.rng.below(3) {
            0 => tweaked.nodes[idx].attrs.padding += 1,
            1 => tweaked.nodes[idx].attrs.groups += 1,
            _ => {
                let a = &mut tweaked.nodes[idx].attrs;
                a.units = Some(a.units.unwrap_or(0) + 1);
            }
        }
        prop_assert!(
            Fingerprint::of_graph(&tweaked) != base,
            "attr tweak on node {idx} ({}) did not change the fingerprint",
            tweaked.nodes[idx].op
        );
        // Batch changes are semantic too.
        let mut rebatched = graph.clone();
        rebatched.batch *= 2;
        for node in &mut rebatched.nodes {
            if !node.out_shape.is_empty() {
                node.out_shape[0] *= 2;
            }
        }
        prop_assert!(Fingerprint::of_graph(&rebatched) != base);
        Ok(())
    });
}

#[test]
fn fingerprint_is_stable_across_processes() {
    // Pinned value: the fingerprint must never depend on process-random
    // state (ASLR, std's randomized hasher). If this changes, the on-wire
    // cache key format changed — bump deliberately.
    let g = Family::ResNet.generate(0);
    let a = Fingerprint::of_graph(&g);
    let b = Fingerprint::of_graph(&Family::ResNet.generate(0));
    assert_eq!(a, b);
    assert_eq!(a.to_hex().len(), 32);
}

#[test]
fn distinct_random_graphs_rarely_collide() {
    // 200 structurally distinct graphs (unique conv widths) must produce
    // 200 distinct fingerprints.
    let mut seen = std::collections::HashSet::new();
    for ch in 1..=200usize {
        let mut b = GraphBuilder::new("prop", "collide", 1);
        let x = b.input(vec![1, 3, 16, 16]);
        let c = b.conv_relu(x, ch, 3, 1, 1);
        b.add(OpKind::GlobalAvgPool2d, Attrs::none(), &[c]);
        let fp = Fingerprint::of_graph(&b.finish());
        assert!(seen.insert(fp.as_u128()), "collision at width {ch}");
    }
}

/// The analyze-once tentpole's safety net: for random graphs (and a sweep
/// of every modelgen family below), every quantity the one-pass
/// [`GraphAnalysis`] caches is *bit-identical* to the legacy
/// recompute-from-scratch path. This is what licenses the simulator, the
/// featurizers and the MIG advisor to reuse the analysis without moving a
/// single prediction (or the tier-1 MAPE benches).
#[test]
fn graph_analysis_parity_with_recompute_from_scratch() {
    proptest(40, |g| {
        let graph = random_graph(g);
        let a = GraphAnalysis::of(&graph);

        // Per-node costs.
        prop_assert_eq!(a.costs.len(), graph.n_nodes());
        for (i, node) in graph.nodes.iter().enumerate() {
            prop_assert_eq!(a.costs[i], op_cost(&graph, node));
        }
        // Fused kernel plan.
        prop_assert_eq!(&a.kernels, &fusion::fuse(&graph));
        // Statics (f64 summation order matters — must match exactly).
        prop_assert_eq!(a.statics, static_features(&graph));
        // Fingerprint (the cache-key format must survive the refactor).
        prop_assert_eq!(a.fingerprint, Fingerprint::of_graph(&graph));

        // Simulator entry points: analyzed == per-call, on every profile.
        let sim = Simulator::new();
        for &p in &ALL_PROFILES {
            prop_assert_eq!(sim.latency_s_analyzed(&a, p), sim.latency_s(&graph, p));
            prop_assert_eq!(sim.memory_mb_analyzed(&a, p), sim.memory_mb(&graph, p));
            prop_assert_eq!(sim.energy_j_analyzed(&a, p), sim.energy_j(&graph, p));
            prop_assert_eq!(sim.measure_on_analyzed(&a, p), sim.measure_on(&graph, p));
        }

        // Featurization from cached costs == featurization from scratch.
        let scratch = encode_graph(&graph);
        let analyzed = encode_graph_analyzed(&graph, &a);
        prop_assert_eq!(&scratch.x, &analyzed.x);
        prop_assert_eq!(&scratch.a_hat, &analyzed.a_hat);
        Ok(())
    });
}

#[test]
fn graph_analysis_parity_across_all_modelgen_families() {
    for family in ALL_FAMILIES {
        let graph = family.generate(0);
        let a = GraphAnalysis::of(&graph);
        for (i, node) in graph.nodes.iter().enumerate() {
            assert_eq!(a.costs[i], op_cost(&graph, node), "{family:?} node {i}");
        }
        assert_eq!(a.kernels, fusion::fuse(&graph), "{family:?}");
        assert_eq!(a.statics, static_features(&graph), "{family:?}");
        assert_eq!(a.fingerprint, Fingerprint::of_graph(&graph), "{family:?}");
        let sim = Simulator::new();
        assert_eq!(sim.measure_analyzed(&a), sim.measure(&graph), "{family:?}");

        // Padded featurization (the serving batch layout) agrees too.
        let cfg = FeatureConfig::new(160);
        let feats = dippm::features::NODE_FEATS;
        let (mut x1, mut a1, mut m1) =
            (vec![0.0; 160 * feats], vec![0.0; 160 * 160], vec![0.0; 160]);
        let (mut x2, mut a2, mut m2) =
            (vec![0.0; 160 * feats], vec![0.0; 160 * 160], vec![0.0; 160]);
        fill_padded(&graph, cfg, &mut x1, &mut a1, &mut m1).unwrap();
        fill_padded_analyzed(&graph, &a, cfg, &mut x2, &mut a2, &mut m2).unwrap();
        assert_eq!(x1, x2, "{family:?}");
        assert_eq!(a1, a2, "{family:?}");
        assert_eq!(m1, m2, "{family:?}");
    }
}

#[test]
fn random_graphs_validate_and_post_order_is_complete() {
    proptest(60, |g| {
        let graph = random_graph(g);
        prop_assert!(graph.validate().is_ok(), "{:?}", graph.validate());
        let order = graph.post_order();
        prop_assert_eq!(order.len(), graph.n_nodes());
        Ok(())
    });
}

#[test]
fn featurization_is_deterministic_and_row_normalized() {
    proptest(40, |g| {
        let graph = random_graph(g);
        let f1 = encode_graph(&graph);
        let f2 = encode_graph(&graph);
        prop_assert_eq!(&f1.x, &f2.x);
        for i in 0..f1.n {
            let s: f32 = f1.a_hat[i * f1.n..(i + 1) * f1.n].iter().sum();
            prop_assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
        for &v in &f1.x {
            prop_assert!(v.is_finite());
        }
        Ok(())
    });
}

#[test]
fn simulator_monotone_in_mig_profile() {
    proptest(30, |g| {
        let graph = random_graph(g);
        let sim = Simulator::new();
        let mut last_lat = f64::INFINITY;
        let mut last_mem = 0.0;
        for &p in &ALL_PROFILES {
            let lat = sim.latency_s(&graph, p);
            let mem = sim.memory_mb(&graph, p);
            prop_assert!(lat <= last_lat * 1.0001, "latency not monotone at {p:?}");
            prop_assert!(mem >= last_mem, "memory not monotone at {p:?}");
            prop_assert!(sim.energy_j(&graph, p).is_finite());
            last_lat = lat;
            last_mem = mem;
        }
        Ok(())
    });
}

#[test]
fn simulator_latency_monotone_in_batch() {
    proptest(30, |g| {
        let res = *g.rng.choose(&[32usize, 64]);
        let ch = 8 << g.rng.below(3);
        let layers = g.usize_in(1, 5);
        let build = |batch: usize| {
            let mut b = GraphBuilder::new("prop", &format!("b{batch}"), batch);
            let x = b.input(vec![batch, 3, res, res]);
            let mut h = x;
            for _ in 0..layers {
                h = b.conv_relu(h, ch, 3, 1, 1);
            }
            b.finish()
        };
        let sim = Simulator::new();
        let l1 = sim.latency_s(&build(1), MigProfile::G7_40);
        let l8 = sim.latency_s(&build(8), MigProfile::G7_40);
        prop_assert!(l8 > l1, "batch 8 ({l8}) not slower than batch 1 ({l1})");
        Ok(())
    });
}

#[test]
fn frontend_roundtrip_random_graphs() {
    proptest(25, |g| {
        let graph = random_graph(g);
        for fw in [
            Framework::Native,
            Framework::PyTorch,
            Framework::TensorFlow,
            Framework::Onnx,
            Framework::Paddle,
        ] {
            let text = frontends::export(fw, &graph);
            let parsed = frontends::parse(fw, &text)
                .map_err(|e| format!("{fw:?}: {e}"))?;
            prop_assert!(
                frontends::structurally_equal(&graph, &parsed),
                "{fw:?} altered the graph"
            );
        }
        Ok(())
    });
}

#[test]
fn json_roundtrip_random_values() {
    proptest(80, |g| {
        // Build a random JSON value, stringify, reparse, compare.
        fn random_json(g: &mut Gen, depth: usize) -> Json {
            match if depth > 2 { g.rng.below(4) } else { g.rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.rng.int_in(-1_000_000, 1_000_000) as f64) / 64.0),
                3 => Json::Str(g.string(12)),
                4 => Json::Arr((0..g.usize_in(0, 5)).map(|_| random_json(g, depth + 1)).collect()),
                _ => {
                    let mut o = dippm::util::json::JsonObj::new();
                    for i in 0..g.usize_in(0, 5) {
                        o.insert(format!("k{i}_{}", g.string(4)), random_json(g, depth + 1));
                    }
                    Json::Obj(o)
                }
            }
        }
        let v = random_json(g, 0);
        let compact = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
        prop_assert_eq!(&v, &compact);
        prop_assert_eq!(&v, &pretty);
        Ok(())
    });
}

#[test]
fn splits_always_partition() {
    proptest(50, |g| {
        let n = g.usize_in(1, 500);
        let seed = g.rng.next_u64();
        let s = Splits::fractions(n, 0.7, 0.15, seed);
        let mut all: Vec<usize> = s.train.iter().chain(&s.val).chain(&s.test).copied().collect();
        all.sort_unstable();
        prop_assert_eq!(all, (0..n).collect::<Vec<_>>());
        Ok(())
    });
}

#[test]
fn modelgen_samples_validate_across_grids() {
    proptest(30, |g| {
        let family = *g.rng.choose(&ALL_FAMILIES);
        let idx = g.rng.below(family.grid_size() * 2);
        let graph = family.generate(idx);
        prop_assert!(graph.validate().is_ok());
        prop_assert!(graph.n_nodes() <= 160, "{family:?}[{idx}] = {}", graph.n_nodes());
        // Featurization must accept every generated graph.
        let f = encode_graph(&graph);
        prop_assert_eq!(f.n, graph.n_nodes());
        Ok(())
    });
}

#[test]
fn mig_rule_consistent_with_capacities() {
    proptest(100, |g| {
        let mem = g.f64_in(1.0, 60_000.0);
        match dippm::mig::predict_profile(mem) {
            Some(p) => {
                prop_assert!(mem < p.capacity_mb());
                // It must be the smallest fitting profile.
                for q in ALL_PROFILES {
                    if q.capacity_mb() < p.capacity_mb() {
                        prop_assert!(mem >= q.capacity_mb());
                    }
                }
            }
            None => prop_assert!(mem >= MigProfile::G7_40.capacity_mb()),
        }
        Ok(())
    });
}

#[test]
fn family_generate_is_pure() {
    proptest(20, |g| {
        let family = *g.rng.choose(&ALL_FAMILIES);
        let idx = g.rng.below(family.grid_size());
        prop_assert_eq!(family.generate(idx), family.generate(idx));
        Ok(())
    });
}
