//! Integration: disk persistence of the prediction cache through the
//! journal/manifest/generation store — the kill-and-restart warm-start
//! story, crash/corruption recovery (torn journal tails are truncated,
//! corrupt manifests fall back, a hosed store is a cold start — never a
//! crash), tombstone exclusion, legacy-snapshot migration, and the
//! `cache_save`/`cache_load`/`cache_compact` TCP admin commands.
//!
//! Everything runs hermetically on the simulator backend; the persistence
//! layer under test is identical under PJRT. Store-level crash injection
//! lives in `tests/cache_journal.rs`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use dippm::cache::{CacheConfig, Target};
use dippm::coordinator::{tcp, Coordinator, CoordinatorOptions};
use dippm::ir::Graph;
use dippm::modelgen::Family;
use dippm::util::json::Json;

fn tmp_store(name: &str) -> PathBuf {
    let path = std::env::temp_dir().join(format!(
        "dippm-persist-it-{}-{name}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&path);
    let _ = std::fs::remove_file(&path);
    path
}

fn persistent_options(path: &PathBuf) -> CoordinatorOptions {
    CoordinatorOptions {
        cache: CacheConfig {
            snapshot_path: Some(path.clone()),
            ..Default::default()
        },
        ..Default::default()
    }
}

fn oversized_graph() -> Graph {
    let mut b = dippm::ir::GraphBuilder::new("t", "too-big", 1);
    let x = b.input(vec![1, 8, 16, 16]);
    let mut h = x;
    for _ in 0..220 {
        h = b.conv_relu(h, 8, 3, 1, 1);
    }
    b.finish()
}

/// Journal files currently in a store directory.
fn journal_paths(dir: &PathBuf) -> Vec<PathBuf> {
    std::fs::read_dir(dir)
        .into_iter()
        .flatten()
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .map(|n| n.to_string_lossy().starts_with("journal-"))
                .unwrap_or(false)
        })
        .collect()
}

/// The acceptance-criteria test: populate via SimBackend, flush the
/// journal on graceful shutdown, restart with `--cache-file`, and the same
/// graph+target submit is a hit (backend not invoked) while a second
/// target on the same graph is a miss.
#[test]
fn kill_and_restart_warm_start() {
    let path = tmp_store("warm-start");
    let g = Family::ResNet.generate(2);
    let slice = Target::parse("a100:2g.10gb").unwrap();

    // First life: populate (one full-GPU entry), then graceful shutdown.
    let first_pred = {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        let pred = coord.predict(g.clone()).unwrap();
        assert_eq!(coord.metrics().batches, 1);
        let m = coord.metrics();
        assert!(m.persist_enabled, "store must be active");
        assert!(m.persist_age_s >= 0.0, "persist age reported while active");
        pred
        // <- drop = graceful kill: the Drop impl flushes the journal.
    };
    assert!(path.is_dir(), "shutdown must leave a store directory at {path:?}");

    // Second life: boot from the store (journal replay).
    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    let m0 = coord.metrics();
    assert_eq!(m0.warm_start_entries, 1, "replayed the journal");
    assert_eq!(m0.replayed_records, 1, "one journaled upsert replayed");
    assert_eq!(m0.torn_tail_drops, 0);
    assert_eq!(m0.cache_entries, 1);
    assert_eq!(m0.batches, 0);

    // Same graph + same target: a pure cache hit — the backend is never
    // invoked in this process.
    let revived = coord.predict(g.clone()).unwrap();
    assert_eq!(revived, first_pred);
    let m1 = coord.metrics();
    assert_eq!(m1.cache_hits, 1);
    assert_eq!(m1.batches, 0, "warm-start hit must not reach the backend");

    // Same graph, different target device: a miss — composite keys keep
    // per-target entries separate across the restart too.
    let sliced = coord
        .predict_to(g.clone(), Some(slice.clone()))
        .unwrap();
    let m2 = coord.metrics();
    assert_eq!(m2.batches, 1, "second target must execute");
    assert_eq!(m2.cache_misses, 1);
    assert!(sliced.latency_ms > revived.latency_ms);
    drop(coord);

    // Third life: both entries survived the second shutdown.
    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    assert_eq!(coord.metrics().warm_start_entries, 2);
    coord.predict(g.clone()).unwrap();
    coord.predict_to(g, Some(slice)).unwrap();
    let m3 = coord.metrics();
    assert_eq!(m3.cache_hits, 2);
    assert_eq!(m3.batches, 0);
    drop(coord);
    let _ = std::fs::remove_dir_all(&path);
}

#[test]
fn corrupt_manifest_still_warm_starts_via_journal_replay() {
    let path = tmp_store("corrupt-manifest");
    {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        coord.predict(Family::Vgg.generate(1)).unwrap();
    }
    // Flip one byte in the manifest: the journal files still carry every
    // committed record, so recovery replays them instead of cold-starting.
    let manifest = path.join("MANIFEST");
    let mut bytes = std::fs::read(&manifest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&manifest, &bytes).unwrap();

    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    let m = coord.metrics();
    assert_eq!(m.warm_start_entries, 1, "journal replay rescues the state");
    assert_eq!(m.batches, 0);
    coord.predict(Family::Vgg.generate(1)).unwrap();
    assert_eq!(coord.metrics().batches, 0, "recovered entry serves the hit");
    drop(coord);
    let _ = std::fs::remove_dir_all(&path);
}

#[test]
fn torn_journal_tail_is_truncated_not_a_cold_start() {
    let path = tmp_store("torn-tail");
    {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        coord.predict(Family::MobileNet.generate(0)).unwrap();
        coord.predict(Family::Vgg.generate(0)).unwrap();
    }
    // Append garbage to one journal file: a torn tail from a mid-append
    // crash. Every fully-written record before it must survive.
    let journals = journal_paths(&path);
    assert!(!journals.is_empty(), "shutdown flush must write journals");
    let victim = &journals[0];
    let mut bytes = std::fs::read(victim).unwrap();
    bytes.extend_from_slice(&[0xAB; 9]);
    std::fs::write(victim, &bytes).unwrap();

    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    let m = coord.metrics();
    assert_eq!(m.torn_tail_drops, 1, "the torn tail is counted");
    assert_eq!(m.warm_start_entries, 2, "committed records all recovered");
    assert_eq!(m.batches, 0);
    drop(coord);
    let _ = std::fs::remove_dir_all(&path);
}

#[test]
fn hosed_store_is_a_cold_start_not_a_crash() {
    let path = tmp_store("hosed");
    {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        coord.predict(Family::MobileNet.generate(0)).unwrap();
    }
    // Scorch the earth: garbage manifest, no journals, no generations.
    for entry in std::fs::read_dir(&path).unwrap().flatten() {
        let _ = std::fs::remove_file(entry.path());
    }
    std::fs::write(path.join("MANIFEST"), b"not a manifest at all").unwrap();

    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    let m = coord.metrics();
    assert_eq!(m.warm_start_entries, 0, "nothing recoverable => cold");
    assert_eq!(m.cache_entries, 0);
    // And the server still serves — and persists again.
    coord.predict(Family::MobileNet.generate(0)).unwrap();
    assert_eq!(coord.metrics().batches, 1);
    drop(coord);
    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    assert_eq!(coord.metrics().warm_start_entries, 1, "persistence recovered");
    drop(coord);
    let _ = std::fs::remove_dir_all(&path);
}

#[test]
fn legacy_snapshot_file_is_migrated_to_a_store() {
    let path = tmp_store("legacy-migrate");
    // Write a PR 2-era single-file snapshot at the --cache-file path by
    // exporting a populated in-memory cache with the legacy codec.
    let g = Family::EfficientNet.generate(2);
    {
        use dippm::cache::persist::save_snapshot;
        use dippm::cache::ShardedLruCache;
        use dippm::coordinator::CacheValue;
        let staging: ShardedLruCache<CacheValue> =
            ShardedLruCache::new(&CacheConfig::default());
        let coord = Coordinator::start_sim(CoordinatorOptions::default()).unwrap();
        let pred = coord.predict(g.clone()).unwrap();
        staging.insert(
            dippm::cache::CacheKey::of(&g, &Target::default()),
            CacheValue::Pred(pred),
        );
        save_snapshot(&path, &staging).unwrap();
    }
    assert!(path.is_file(), "legacy snapshot is a single file");

    // Booting with --cache-file at that path migrates it into a store dir
    // and warm-starts from its entries.
    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    assert!(path.is_dir(), "migration replaces the file with a store");
    let m = coord.metrics();
    assert_eq!(m.warm_start_entries, 1);
    coord.predict(g).unwrap();
    assert_eq!(coord.metrics().batches, 0, "migrated entry serves the hit");
    drop(coord);
    let _ = std::fs::remove_dir_all(&path);
}

#[test]
fn tombstones_do_not_survive_restart() {
    let path = tmp_store("tombstones");
    {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        coord.predict(Family::Vgg.generate(0)).unwrap();
        coord.predict(oversized_graph()).unwrap_err();
        let m = coord.metrics();
        assert_eq!(m.cache_entries, 2, "prediction + tombstone in memory");
    }
    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    let m = coord.metrics();
    assert_eq!(
        m.warm_start_entries, 1,
        "only the real prediction is journaled"
    );
    // The poison graph executes again (and fails again) after restart.
    coord.predict(oversized_graph()).unwrap_err();
    assert_eq!(coord.metrics().errors, 1);
    drop(coord);
    let _ = std::fs::remove_dir_all(&path);
}

#[test]
fn journal_entries_respect_cache_ttl_across_restart() {
    let path = tmp_store("ttl");
    let ttl_options = |ttl: Duration| CoordinatorOptions {
        cache: CacheConfig {
            snapshot_path: Some(path.clone()),
            ttl: Some(ttl),
            ..Default::default()
        },
        ..Default::default()
    };
    {
        let coord = Coordinator::start_sim(ttl_options(Duration::from_secs(3600))).unwrap();
        coord.predict(Family::ResNet.generate(0)).unwrap();
        // Age the entry before the shutdown flush records its age.
        std::thread::sleep(Duration::from_millis(60));
    }
    // Restart with a tiny TTL: the journaled upsert's recorded age already
    // exceeds it (entries are backdated, not reborn), so replay skips it.
    let coord = Coordinator::start_sim(ttl_options(Duration::from_millis(50))).unwrap();
    assert_eq!(coord.metrics().warm_start_entries, 0, "aged-out entry skipped");
    drop(coord);
    let coord = Coordinator::start_sim(ttl_options(Duration::from_secs(3600))).unwrap();
    assert_eq!(
        coord.metrics().warm_start_entries,
        0,
        "previous boot persisted an empty cache"
    );
    drop(coord);
    let _ = std::fs::remove_dir_all(&path);
}

#[test]
fn periodic_timer_flushes_journal_without_shutdown() {
    let path = tmp_store("periodic");
    let coord = Coordinator::start_sim(CoordinatorOptions {
        cache: CacheConfig {
            snapshot_path: Some(path.clone()),
            snapshot_every: Some(Duration::from_millis(40)),
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    coord.predict(Family::DenseNet.generate(1)).unwrap();
    // Wait until a timer flush appends the insert to a journal file (the
    // 24-byte file header alone means no records yet).
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let has_record = |dir: &PathBuf| {
        journal_paths(dir).iter().any(|p| {
            std::fs::metadata(p).map(|m| m.len() > 24).unwrap_or(false)
        })
    };
    while !has_record(&path) && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(has_record(&path), "timer must flush journaled records");
    assert!(coord.metrics().journal_appends >= 1);
    // The flushed store is valid and loadable by a sibling server.
    let sibling_path = tmp_store("periodic-sib");
    let other = Coordinator::start_sim(persistent_options(&sibling_path)).unwrap();
    let report = other.load_cache(Some(path.to_str().unwrap())).unwrap();
    assert_eq!(report.entries, 1);
    assert_eq!(other.metrics().warm_start_entries, 1);
    drop(coord);
    drop(other);
    let _ = std::fs::remove_dir_all(&path);
    let _ = std::fs::remove_dir_all(&sibling_path);
}

#[test]
fn compaction_folds_journal_and_restart_reads_the_generation() {
    let path = tmp_store("compact");
    {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        coord.predict(Family::Vgg.generate(0)).unwrap();
        coord.predict(Family::ResNet.generate(1)).unwrap();
        let report = coord.compact_cache().unwrap();
        assert_eq!(report.entries, 2);
        assert!(report.generation >= 2);
        let m = coord.metrics();
        assert_eq!(m.compactions, 1);
        assert_eq!(m.journal_generation, report.generation);
    }
    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    let m = coord.metrics();
    assert_eq!(m.warm_start_entries, 2);
    // Entries now come from the generation base, not journal replay.
    assert_eq!(m.replayed_records, 0);
    drop(coord);
    let _ = std::fs::remove_dir_all(&path);
}

#[test]
fn cache_save_load_and_compact_tcp_commands() {
    let path = tmp_store("tcp-cmd");
    let coord = Arc::new(Coordinator::start_sim(CoordinatorOptions::default()).unwrap());
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    {
        let coord = coord.clone();
        std::thread::spawn(move || {
            tcp::serve(coord, "127.0.0.1:0", move |p| {
                let _ = port_tx.send(p);
            })
            .unwrap();
        });
    }
    let port = port_rx.recv().unwrap();
    let mut client = tcp::Client::connect(&format!("127.0.0.1:{port}")).unwrap();

    // No --cache-file configured and no path given: structured errors.
    let resp = client.cache_save(None).unwrap();
    assert!(resp.contains("\"ok\":false"), "{resp}");
    let resp = client.cache_compact().unwrap();
    assert!(resp.contains("\"ok\":false"), "{resp}");

    // cache_stats must still report the persistence fields on this
    // persistence-less server (cold boot): present, zeroed, age -1.
    let stats = Json::parse(&client.cache_stats().unwrap()).unwrap();
    assert_eq!(stats.path(&["persist_enabled"]).as_bool(), Some(false));
    assert_eq!(stats.path(&["warm_start_entries"]).as_usize(), Some(0));
    assert_eq!(stats.path(&["journal_appends"]).as_usize(), Some(0));
    assert_eq!(stats.path(&["torn_tail_drops"]).as_usize(), Some(0));
    assert!(stats.path(&["snapshot_age_s"]).as_f64().unwrap() < 0.0);

    let g = Family::EfficientNet.generate(1);
    client.predict_graph(&g).unwrap();
    let resp = client.cache_save(path.to_str()).unwrap();
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.path(&["ok"]).as_bool(), Some(true), "{resp}");
    assert_eq!(v.path(&["entries"]).as_usize(), Some(1));
    assert!(path.is_dir(), "explicit cache_save writes a store directory");

    // A second server starts cold, loads the store over TCP, then serves
    // the same graph without executing it.
    let coord2 = Arc::new(Coordinator::start_sim(CoordinatorOptions::default()).unwrap());
    let (port_tx2, port_rx2) = std::sync::mpsc::channel();
    {
        let coord2 = coord2.clone();
        std::thread::spawn(move || {
            tcp::serve(coord2, "127.0.0.1:0", move |p| {
                let _ = port_tx2.send(p);
            })
            .unwrap();
        });
    }
    let port2 = port_rx2.recv().unwrap();
    let mut client2 = tcp::Client::connect(&format!("127.0.0.1:{port2}")).unwrap();
    let resp = client2.cache_load(path.to_str()).unwrap();
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.path(&["ok"]).as_bool(), Some(true), "{resp}");
    assert_eq!(v.path(&["entries"]).as_usize(), Some(1));

    let resp = client2.predict_graph(&g).unwrap();
    assert!(resp.contains("\"ok\":true"), "{resp}");
    let m = coord2.metrics();
    assert_eq!(m.batches, 0, "loaded entry served the request");
    assert_eq!(m.cache_hits, 1);
    assert_eq!(m.warm_start_entries, 1);

    // Loading a nonexistent store over TCP is a structured error.
    let resp = client2.cache_load(Some("/nonexistent/cache-store")).unwrap();
    assert!(resp.contains("\"ok\":false"), "{resp}");
    let _ = std::fs::remove_dir_all(&path);
}

#[test]
fn cache_compact_tcp_command_on_a_persistent_server() {
    let path = tmp_store("tcp-compact");
    let coord = Arc::new(Coordinator::start_sim(persistent_options(&path)).unwrap());
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    {
        let coord = coord.clone();
        std::thread::spawn(move || {
            tcp::serve(coord, "127.0.0.1:0", move |p| {
                let _ = port_tx.send(p);
            })
            .unwrap();
        });
    }
    let port = port_rx.recv().unwrap();
    let mut client = tcp::Client::connect(&format!("127.0.0.1:{port}")).unwrap();
    client.predict_graph(&Family::Vgg.generate(2)).unwrap();

    let resp = client.cache_compact().unwrap();
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.path(&["ok"]).as_bool(), Some(true), "{resp}");
    assert_eq!(v.path(&["cmd"]).as_str(), Some("cache_compact"));
    assert_eq!(v.path(&["entries"]).as_usize(), Some(1));

    let stats = Json::parse(&client.cache_stats().unwrap()).unwrap();
    assert_eq!(stats.path(&["persist_enabled"]).as_bool(), Some(true));
    assert_eq!(stats.path(&["compactions"]).as_usize(), Some(1));
    assert!(stats.path(&["snapshot_age_s"]).as_f64().unwrap() >= 0.0);
    let _ = std::fs::remove_dir_all(&path);
}
