//! Integration: disk persistence of the prediction cache — the
//! kill-and-restart warm-start story, snapshot integrity (corruption ⇒
//! cold start, not a crash), periodic snapshot rotation, tombstone
//! exclusion, and the `cache_save`/`cache_load` TCP admin commands.
//!
//! Everything runs hermetically on the simulator backend; the persistence
//! layer under test is identical under PJRT.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use dippm::cache::{CacheConfig, Target};
use dippm::coordinator::{tcp, Coordinator, CoordinatorOptions};
use dippm::ir::Graph;
use dippm::modelgen::Family;
use dippm::util::json::Json;

fn tmp_snapshot(name: &str) -> PathBuf {
    let path = std::env::temp_dir().join(format!(
        "dippm-persist-it-{}-{name}.bin",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    path
}

fn persistent_options(path: &PathBuf) -> CoordinatorOptions {
    CoordinatorOptions {
        cache: CacheConfig {
            snapshot_path: Some(path.clone()),
            ..Default::default()
        },
        ..Default::default()
    }
}

fn oversized_graph() -> Graph {
    let mut b = dippm::ir::GraphBuilder::new("t", "too-big", 1);
    let x = b.input(vec![1, 8, 16, 16]);
    let mut h = x;
    for _ in 0..220 {
        h = b.conv_relu(h, 8, 3, 1, 1);
    }
    b.finish()
}

/// The acceptance-criteria test: populate via SimBackend, snapshot on
/// graceful shutdown, restart with `--cache-file`, and the same
/// graph+target submit is a hit (backend not invoked) while a second
/// target on the same graph is a miss.
#[test]
fn kill_and_restart_warm_start() {
    let path = tmp_snapshot("warm-start");
    let g = Family::ResNet.generate(2);
    let slice = Target::parse("a100:2g.10gb").unwrap();

    // First life: populate (one full-GPU entry), then graceful shutdown.
    let first_pred = {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        let pred = coord.predict(g.clone()).unwrap();
        assert_eq!(coord.metrics().batches, 1);
        pred
        // <- drop = kill: the Drop impl writes the snapshot.
    };
    assert!(path.exists(), "graceful shutdown must write {path:?}");

    // Second life: boot from the snapshot.
    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    let m0 = coord.metrics();
    assert_eq!(m0.warm_start_entries, 1, "preloaded the snapshot");
    assert_eq!(m0.cache_entries, 1);
    assert_eq!(m0.batches, 0);

    // Same graph + same target: a pure cache hit — the backend is never
    // invoked in this process.
    let revived = coord.predict(g.clone()).unwrap();
    assert_eq!(revived, first_pred);
    let m1 = coord.metrics();
    assert_eq!(m1.cache_hits, 1);
    assert_eq!(m1.batches, 0, "warm-start hit must not reach the backend");

    // Same graph, different target device: a miss — composite keys keep
    // per-target entries separate across the restart too.
    let sliced = coord
        .predict_to(g.clone(), Some(slice.clone()))
        .unwrap();
    let m2 = coord.metrics();
    assert_eq!(m2.batches, 1, "second target must execute");
    assert_eq!(m2.cache_misses, 1);
    assert!(sliced.latency_ms > revived.latency_ms);
    drop(coord);

    // Third life: both entries survived the second shutdown.
    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    assert_eq!(coord.metrics().warm_start_entries, 2);
    coord.predict(g.clone()).unwrap();
    coord.predict_to(g, Some(slice)).unwrap();
    let m3 = coord.metrics();
    assert_eq!(m3.cache_hits, 2);
    assert_eq!(m3.batches, 0);
    drop(coord);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupted_snapshot_is_a_cold_start_not_a_crash() {
    let path = tmp_snapshot("corrupt");
    {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        coord.predict(Family::Vgg.generate(1)).unwrap();
    }
    // Flip one byte in the middle of the file.
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&path, &bytes).unwrap();

    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    let m = coord.metrics();
    assert_eq!(m.warm_start_entries, 0, "rejected snapshot => cold");
    assert_eq!(m.cache_entries, 0);
    // And the server still serves.
    coord.predict(Family::Vgg.generate(1)).unwrap();
    assert_eq!(coord.metrics().batches, 1);
    drop(coord);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn truncated_snapshot_is_a_cold_start_not_a_crash() {
    let path = tmp_snapshot("truncated");
    {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        coord.predict(Family::MobileNet.generate(0)).unwrap();
    }
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();

    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    assert_eq!(coord.metrics().warm_start_entries, 0);
    coord.predict(Family::MobileNet.generate(0)).unwrap();
    drop(coord);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tombstones_do_not_survive_restart() {
    let path = tmp_snapshot("tombstones");
    {
        let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
        coord.predict(Family::Vgg.generate(0)).unwrap();
        coord.predict(oversized_graph()).unwrap_err();
        let m = coord.metrics();
        assert_eq!(m.cache_entries, 2, "prediction + tombstone in memory");
    }
    let coord = Coordinator::start_sim(persistent_options(&path)).unwrap();
    let m = coord.metrics();
    assert_eq!(
        m.warm_start_entries, 1,
        "only the real prediction is snapshotted"
    );
    // The poison graph executes again (and fails again) after restart.
    coord.predict(oversized_graph()).unwrap_err();
    assert_eq!(coord.metrics().errors, 1);
    drop(coord);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn snapshot_entries_respect_cache_ttl_across_restart() {
    let path = tmp_snapshot("ttl");
    let ttl_options = |ttl: Duration| CoordinatorOptions {
        cache: CacheConfig {
            snapshot_path: Some(path.clone()),
            ttl: Some(ttl),
            ..Default::default()
        },
        ..Default::default()
    };
    {
        let coord = Coordinator::start_sim(ttl_options(Duration::from_secs(3600))).unwrap();
        coord.predict(Family::ResNet.generate(0)).unwrap();
        // Age the entry before the shutdown snapshot records its age.
        std::thread::sleep(Duration::from_millis(60));
    }
    // Restart with a tiny TTL: the snapshot entry's recorded age already
    // exceeds it (entries are backdated, not reborn), so the boot preload
    // skips it.
    let coord = Coordinator::start_sim(ttl_options(Duration::from_millis(50))).unwrap();
    assert_eq!(coord.metrics().warm_start_entries, 0, "aged-out entry skipped");
    // And with a generous TTL it is preloaded.
    drop(coord);
    let coord = Coordinator::start_sim(ttl_options(Duration::from_secs(3600))).unwrap();
    assert_eq!(coord.metrics().warm_start_entries, 0, "previous boot saved an empty cache");
    drop(coord);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn periodic_snapshot_timer_rotates_without_shutdown() {
    let path = tmp_snapshot("periodic");
    let coord = Coordinator::start_sim(CoordinatorOptions {
        cache: CacheConfig {
            snapshot_path: Some(path.clone()),
            snapshot_every: Some(Duration::from_millis(40)),
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    coord.predict(Family::DenseNet.generate(1)).unwrap();
    // Wait until a rotation lands that contains the entry: an empty
    // snapshot is exactly 28 bytes (header + count + checksum), so watch
    // for a bigger file (rename makes every observation a complete file).
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let has_entry = |p: &PathBuf| std::fs::metadata(p).map(|m| m.len() > 28).unwrap_or(false);
    while !has_entry(&path) && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(has_entry(&path), "timer must rotate a populated snapshot");
    // The rotated snapshot is valid and loadable by a sibling server.
    let sibling_path = tmp_snapshot("periodic-sib");
    let other = Coordinator::start_sim(persistent_options(&sibling_path)).unwrap();
    let report = other.load_cache(Some(path.to_str().unwrap())).unwrap();
    assert_eq!(report.entries, 1);
    assert_eq!(other.metrics().warm_start_entries, 1);
    drop(coord);
    drop(other);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&sibling_path);
}

#[test]
fn cache_save_and_load_tcp_commands() {
    let path = tmp_snapshot("tcp-cmd");
    let coord = Arc::new(Coordinator::start_sim(CoordinatorOptions::default()).unwrap());
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    {
        let coord = coord.clone();
        std::thread::spawn(move || {
            tcp::serve(coord, "127.0.0.1:0", move |p| {
                let _ = port_tx.send(p);
            })
            .unwrap();
        });
    }
    let port = port_rx.recv().unwrap();
    let mut client = tcp::Client::connect(&format!("127.0.0.1:{port}")).unwrap();

    // No --cache-file configured and no path given: structured error.
    let resp = client.cache_save(None).unwrap();
    assert!(resp.contains("\"ok\":false"), "{resp}");

    let g = Family::EfficientNet.generate(1);
    client.predict_graph(&g).unwrap();
    let resp = client.cache_save(path.to_str()).unwrap();
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.path(&["ok"]).as_bool(), Some(true), "{resp}");
    assert_eq!(v.path(&["entries"]).as_usize(), Some(1));
    assert!(path.exists());

    // A second server starts cold, loads the snapshot over TCP, then
    // serves the same graph without executing it.
    let coord2 = Arc::new(Coordinator::start_sim(CoordinatorOptions::default()).unwrap());
    let (port_tx2, port_rx2) = std::sync::mpsc::channel();
    {
        let coord2 = coord2.clone();
        std::thread::spawn(move || {
            tcp::serve(coord2, "127.0.0.1:0", move |p| {
                let _ = port_tx2.send(p);
            })
            .unwrap();
        });
    }
    let port2 = port_rx2.recv().unwrap();
    let mut client2 = tcp::Client::connect(&format!("127.0.0.1:{port2}")).unwrap();
    let resp = client2.cache_load(path.to_str()).unwrap();
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.path(&["ok"]).as_bool(), Some(true), "{resp}");
    assert_eq!(v.path(&["entries"]).as_usize(), Some(1));

    let resp = client2.predict_graph(&g).unwrap();
    assert!(resp.contains("\"ok\":true"), "{resp}");
    let m = coord2.metrics();
    assert_eq!(m.batches, 0, "loaded entry served the request");
    assert_eq!(m.cache_hits, 1);
    assert_eq!(m.warm_start_entries, 1);

    // Loading a nonexistent file over TCP is a structured error.
    let resp = client2.cache_load(Some("/nonexistent/cache.bin")).unwrap();
    assert!(resp.contains("\"ok\":false"), "{resp}");
    let _ = std::fs::remove_file(&path);
}
