//! Integration: the batch-former pipeline (queue → former → handoff ring
//! → workers) across all three `--batch-former` modes, on the hermetic
//! simulator backend.
//!
//! The contracts under test:
//!
//! * mode equivalence — `off`/`thread`/`leader` serve identical answers;
//! * the one-`max_wait` residency bound — under a trickle with
//!   `--executor-threads 4`, no request's queue residency (enqueue →
//!   batch admission, measured inside the queue as
//!   `queue_residency_max_us`) exceeds one `max_wait`;
//! * steal-on-empty-ring — with one worker blocked inside its backend, an
//!   idle worker steals the former role and serves new traffic instead of
//!   sleeping;
//! * drain-on-shutdown — dropping the coordinator with jobs queued still
//!   delivers every reply (queue drains into closed batches, the ring
//!   drains into workers, then everyone exits);
//! * the latency histogram and depth gauges are live end to end.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use dippm::coordinator::{
    Backend, BatchFormerMode, Coordinator, CoordinatorOptions, PredictRequest, RawOutcome,
};
use dippm::modelgen::{Family, ALL_FAMILIES};

fn opts(mode: BatchFormerMode, threads: usize, max_wait: Duration) -> CoordinatorOptions {
    CoordinatorOptions {
        executor_threads: threads,
        batch_former: mode,
        max_wait,
        ..Default::default()
    }
}

const ALL_MODES: [BatchFormerMode; 3] = [
    BatchFormerMode::Off,
    BatchFormerMode::Thread,
    BatchFormerMode::Leader,
];

/// Workers reply before folding counters into `Metrics` (by design — no
/// lock is held while senders run), so a metrics read racing the fold can
/// momentarily under-count. Poll until `cond` holds (or time out and
/// return the last snapshot for the assertion message).
fn metrics_when(
    coord: &Coordinator,
    cond: impl Fn(&dippm::coordinator::Metrics) -> bool,
) -> dippm::coordinator::Metrics {
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let m = coord.metrics();
        if cond(&m) || std::time::Instant::now() >= deadline {
            return m;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn all_modes_serve_identical_answers() {
    let serial = Coordinator::start_sim(opts(BatchFormerMode::Off, 1, Duration::from_millis(1)))
        .unwrap();
    for mode in ALL_MODES {
        let coord =
            Coordinator::start_sim(opts(mode, 4, Duration::from_millis(1))).unwrap();
        for i in 0..14 {
            let g = Family::MobileNet.generate(i % 7);
            let got = coord.predict(g.clone()).unwrap();
            let want = serial.predict(g).unwrap();
            assert_eq!(got, want, "mode {mode:?} changed an answer");
        }
        let m = coord.metrics();
        assert_eq!(m.errors, 0);
        assert_eq!(m.batch_former, mode.as_str());
        assert_eq!(m.requests, 14);
    }
}

#[test]
fn leader_mode_with_a_single_worker_degenerates_cleanly() {
    // One worker both forms and executes: the pipeline must not deadlock
    // or change answers.
    let coord =
        Coordinator::start_sim(opts(BatchFormerMode::Leader, 1, Duration::from_millis(1)))
            .unwrap();
    let g = Family::ResNet.generate(2);
    let a = coord.predict(g.clone()).unwrap();
    let b = coord.predict(g).unwrap();
    assert_eq!(a, b);
    let m = metrics_when(&coord, |m| m.batches == 1);
    assert_eq!(m.batches, 1, "the repeat is a cache hit");
    assert_eq!(m.cache_hits, 1);
}

/// The acceptance bound: with `--executor-threads 4` under a slow trickle
/// of distinct misses, a former-mode pipeline admits every request within
/// one `max_wait` of its arrival. The gauge is measured inside the queue
/// at admission (execution and reply delivery excluded), and the former's
/// arrival-gap linger closes trickle batches after `max_wait / 8` — so the
/// margin to the bound is ~8x, far beyond scheduler jitter.
#[test]
fn trickle_queue_residency_never_exceeds_one_max_wait() {
    let max_wait = Duration::from_millis(400);
    for mode in [BatchFormerMode::Thread, BatchFormerMode::Leader] {
        let coord = Coordinator::start_sim(opts(mode, 4, max_wait)).unwrap();
        for i in 0..5 {
            // Distinct architectures: every request is a real miss that
            // must be admitted through the former.
            let g = ALL_FAMILIES[(2 * i) % ALL_FAMILIES.len()].generate(i);
            coord.predict(g).unwrap();
            std::thread::sleep(Duration::from_millis(20));
        }
        let m = metrics_when(&coord, |m| m.latency_count() == 5);
        assert_eq!(m.errors, 0);
        assert!(m.queue_residency_max_us > 0, "residency gauge must be live");
        assert!(
            u128::from(m.queue_residency_max_us) <= max_wait.as_micros(),
            "mode {mode:?}: queue residency {}us exceeds one max_wait ({}us)",
            m.queue_residency_max_us,
            max_wait.as_micros()
        );
        // The latency histogram saw every backend-served request.
        assert_eq!(m.latency_count(), 5);
        assert!(m.latency_p50_us() > 0);
        assert!(m.latency_p50_us() <= m.latency_p99_us());
        assert!(m.latency_p99_us() <= m.latency_max_us());
    }
}

/// A backend whose very first `predict_into` (across all workers) blocks
/// until the test opens the gate — the tool for wedging one worker while
/// the others must keep the pipeline alive.
struct FirstCallGate {
    /// (armed, open) — the first caller disarms and then waits for open.
    state: Arc<(Mutex<(bool, bool)>, Condvar)>,
}

impl Backend for FirstCallGate {
    fn name(&self) -> &'static str {
        "first-call-gate"
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn predict_into(
        &mut self,
        requests: &[PredictRequest<'_>],
        out: &mut Vec<RawOutcome>,
    ) -> anyhow::Result<()> {
        let (lock, cv) = &*self.state;
        let mut s = lock.lock().unwrap();
        if s.0 {
            s.0 = false; // disarm: only the very first call blocks
            while !s.1 {
                s = cv.wait(s).unwrap();
            }
        }
        drop(s);
        out.extend(
            requests
                .iter()
                .map(|req| Ok([1.0, 100.0 + req.graph.n_nodes() as f64, 1.0])),
        );
        Ok(())
    }
}

#[test]
fn idle_worker_steals_the_former_role_while_a_worker_is_wedged() {
    let state = Arc::new((Mutex::new((true, false)), Condvar::new()));
    let coord = {
        let state = state.clone();
        Coordinator::start_with_backend(
            Box::new(move || {
                Ok(Box::new(FirstCallGate {
                    state: state.clone(),
                }) as Box<dyn Backend>)
            }),
            opts(BatchFormerMode::Leader, 2, Duration::from_millis(2)),
        )
        .unwrap()
    };
    // First submission wedges whichever worker executes it.
    let rx_wedged = coord.submit(Family::Vgg.generate(0));
    // Wait until the gate is actually held.
    loop {
        if !state.0.lock().unwrap().0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    // With one worker wedged mid-batch, the other must steal the former
    // role (the ring is empty), form the next batch and execute it —
    // if it were sleeping on the ring instead, this recv would time out.
    let rx_live = coord.submit(Family::ResNet.generate(0));
    let pred = rx_live
        .recv_timeout(Duration::from_secs(10))
        .expect("an idle worker must keep serving while a peer is wedged")
        .unwrap();
    assert!(pred.memory_mb > 0.0);
    // Open the gate; the wedged request completes too.
    {
        let (lock, cv) = &*state;
        lock.lock().unwrap().1 = true;
        cv.notify_all();
    }
    rx_wedged
        .recv_timeout(Duration::from_secs(10))
        .expect("wedged request completes once the gate opens")
        .unwrap();
    let m = metrics_when(&coord, |m| m.batches == 2);
    assert_eq!(m.errors, 0);
    assert_eq!(m.batches, 2, "max_batch=1: one batch per miss");
}

#[test]
fn shutdown_drains_queued_jobs_in_every_mode() {
    for mode in ALL_MODES {
        let coord =
            Coordinator::start_sim(opts(mode, 2, Duration::from_millis(5))).unwrap();
        // A burst of distinct misses, then an immediate drop: the queue is
        // closed, the former folds the remainder into closed batches, the
        // workers drain the ring, and only then does drop return.
        let rxs: Vec<_> = (0..ALL_FAMILIES.len())
            .map(|i| coord.submit(ALL_FAMILIES[i].generate(0)))
            .collect();
        drop(coord);
        for (i, rx) in rxs.into_iter().enumerate() {
            let pred = rx
                .recv()
                .unwrap_or_else(|_| panic!("mode {mode:?}: reply {i} dropped on shutdown"))
                .unwrap();
            assert!(pred.latency_ms.is_finite());
        }
    }
}

#[test]
fn ring_and_queue_gauges_settle_after_a_burst() {
    let coord =
        Coordinator::start_sim(opts(BatchFormerMode::Thread, 3, Duration::from_millis(2)))
            .unwrap();
    let rxs: Vec<_> = (0..ALL_FAMILIES.len())
        .map(|i| coord.submit(ALL_FAMILIES[i].generate(1)))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let m = metrics_when(&coord, |m| m.latency_count() == ALL_FAMILIES.len() as u64);
    assert_eq!(m.queue_depth, 0, "all jobs admitted");
    assert_eq!(m.ring_depth, 0, "all batches executed");
    assert!(m.queue_depth_hwm >= 1, "the burst was visible to the gauge");
    assert_eq!(m.latency_count(), ALL_FAMILIES.len() as u64);
    assert_eq!(m.batch_former, "thread");
}

/// Deterministic no-double-wait at the pipeline level: a single miss
/// through a 4-worker former pipeline replies well before two `max_wait`
/// windows could elapse — in the per-worker design, a second camper's
/// window was the failure mode this pipeline removes.
#[test]
fn single_miss_never_waits_two_windows() {
    let max_wait = Duration::from_millis(300);
    let coord = Coordinator::start_sim(opts(BatchFormerMode::Leader, 4, max_wait)).unwrap();
    let t0 = std::time::Instant::now();
    coord.predict(Family::DenseNet.generate(3)).unwrap();
    let elapsed = t0.elapsed();
    assert!(
        elapsed < max_wait * 2,
        "one miss must never span two windows: {elapsed:?} vs max_wait {max_wait:?}"
    );
    let m = metrics_when(&coord, |m| m.batches >= 1);
    assert!(u128::from(m.queue_residency_max_us) <= max_wait.as_micros());
}
