//! Integration: the sharded coordinator fleet — consistent-hash routing
//! through the fleet router must be bit-identical to a single coordinator,
//! a killed replica's shard must fail over to a live peer with no
//! client-visible error, and a cold replica must warm-start from a peer's
//! committed manifest + generation files (`warm_start_entries > 0`,
//! zero backend recomputation).
//!
//! The failover and CLI warm-start tests drive real `dippm serve` child
//! processes (the only way to kill a replica mid-stream); everything else
//! runs hermetically in-process on `SimBackend`.
//!
//! Set `DIPPM_FLEET_TEST_DIR` to root the store directories somewhere
//! persistent (the CI `fleet-smoke` job points it at the workspace and
//! uploads the directories on failure); cleanup happens only on success.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use dippm::cache::{CacheConfig, CacheKey, Target};
use dippm::coordinator::{
    Coordinator, CoordinatorOptions, Prediction, SweepEvent, SweepItem, SweepSpec,
};
use dippm::fleet::replicate_from_peer;
use dippm::fleet::router::{self, HashRing, RouterConfig};
use dippm::ir::{DType, Graph};
use dippm::modelgen::{Family, ALL_FAMILIES};
use dippm::simulator::CostSweep;
use dippm::util::json::Json;
use dippm::wire::{reactor, ReactorConfig, WireClient};

static DIR_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Fresh store directory under `DIPPM_FLEET_TEST_DIR` (CI artifact root)
/// or the system temp dir.
fn fleet_dir(name: &str) -> PathBuf {
    let root = std::env::var("DIPPM_FLEET_TEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| std::env::temp_dir());
    let _ = std::fs::create_dir_all(&root);
    let dir = root.join(format!(
        "dippm-fleet-{}-{name}-{}",
        std::process::id(),
        DIR_COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn sim_coordinator() -> Arc<Coordinator> {
    Arc::new(Coordinator::start_sim(CoordinatorOptions::default()).unwrap())
}

/// A coordinator persisting its cache to `dir` — the replication source.
fn sim_coordinator_with_store(dir: &Path) -> Arc<Coordinator> {
    let opts = CoordinatorOptions {
        cache: CacheConfig {
            snapshot_path: Some(dir.to_path_buf()),
            ..CacheConfig::default()
        },
        ..CoordinatorOptions::default()
    };
    Arc::new(Coordinator::start_sim(opts).unwrap())
}

/// Start the binary reactor on an ephemeral port; returns its address.
fn start_reactor(coord: Arc<Coordinator>) -> String {
    let (port_tx, port_rx) = mpsc::channel();
    std::thread::spawn(move || {
        reactor::serve(coord, "127.0.0.1:0", ReactorConfig::default(), move |p| {
            let _ = port_tx.send(p);
        })
        .unwrap();
    });
    format!("127.0.0.1:{}", port_rx.recv().unwrap())
}

/// Start the fleet router over `replicas` on an ephemeral port. A fast
/// probe cadence keeps the kill-one test's health convergence quick.
fn start_router(replicas: Vec<String>) -> String {
    let (port_tx, port_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let cfg = RouterConfig {
            replicas,
            health_interval: Duration::from_millis(200),
            ..RouterConfig::default()
        };
        router::serve("127.0.0.1:0", cfg, move |p| {
            let _ = port_tx.send(p);
        })
        .unwrap();
    });
    format!("127.0.0.1:{}", port_rx.recv().unwrap())
}

/// A real `dippm serve` replica process — killable, unlike an in-process
/// reactor thread. The bound port is scraped from the startup banner.
struct ChildReplica {
    child: Child,
    addr: String,
}

impl ChildReplica {
    fn spawn(extra: &[&str]) -> ChildReplica {
        let mut child = Command::new(env!("CARGO_BIN_EXE_dippm"))
            .args([
                "serve",
                "--backend",
                "sim",
                "--wire",
                "binary",
                "--addr",
                "127.0.0.1:0",
                "--fleet",
                "replica",
            ])
            .args(extra)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn dippm replica");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut lines = BufReader::new(stdout).lines();
        let addr = loop {
            match lines.next() {
                Some(Ok(line)) => {
                    if let Some(rest) = line.strip_prefix("listening on port ") {
                        let port: String =
                            rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                        break format!("127.0.0.1:{port}");
                    }
                }
                _ => panic!("replica exited before printing its port"),
            }
        };
        // Keep draining the pipe so the child never blocks on a full one.
        std::thread::spawn(move || {
            for _ in lines {}
        });
        ChildReplica { child, addr }
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ChildReplica {
    fn drop(&mut self) {
        self.kill();
    }
}

/// A deterministic request stream touching every model family.
fn request_stream(seeds: std::ops::Range<usize>) -> Vec<Graph> {
    ALL_FAMILIES
        .iter()
        .flat_map(|f| seeds.clone().map(move |s| f.generate(s)))
        .collect()
}

// -------------------------------------------------------------- routing --

/// Acceptance: three SimBackend replicas behind the router serve
/// bit-identical predictions to a single coordinator for the same
/// request stream — and the ring actually spreads that stream.
#[test]
fn fleet_parity_with_single_coordinator() {
    let reference = sim_coordinator();
    let replicas: Vec<String> = (0..3).map(|_| start_reactor(sim_coordinator())).collect();
    let router_addr = start_router(replicas);
    let mut client = WireClient::connect(&router_addr).unwrap();

    let graphs = request_stream(0..3);
    for g in &graphs {
        let want = reference.predict_to(g.clone(), None).unwrap();
        let got = client.predict_graph(g).unwrap();
        assert_eq!(got, want, "prediction diverged through the router");
    }

    let stats = Json::parse(&client.fleet_stats().unwrap()).unwrap();
    assert_eq!(stats.path(&["ok"]).as_bool(), Some(true));
    assert_eq!(stats.path(&["alive"]).as_usize(), Some(3));
    let rows = stats.path(&["replica_stats"]).as_arr().unwrap();
    let routed: usize = rows
        .iter()
        .map(|r| r.path(&["routed"]).as_usize().unwrap())
        .sum();
    assert_eq!(routed, graphs.len(), "every request routes exactly once");
    let busy = rows
        .iter()
        .filter(|r| r.path(&["routed"]).as_usize().unwrap() > 0)
        .count();
    assert!(busy >= 2, "all traffic landed on one replica: {stats}");
    // A healthy sequential stream never fails over.
    let failed: usize = rows
        .iter()
        .map(|r| r.path(&["failed_over"]).as_usize().unwrap())
        .sum();
    assert_eq!(failed, 0, "spurious failover on a healthy fleet: {stats}");
}

/// The stats/replication verbs answer at the right layer: replicas serve
/// `shard_stats` + manifest fetches, the router serves `fleet_stats`
/// (echoing the plain `stats` verb too), and each side rejects the
/// other's verbs with a request-level error, not a dropped connection.
#[test]
fn stats_verbs_route_to_the_right_layer() {
    let replica = start_reactor(sim_coordinator());
    let router_addr = start_router(vec![replica.clone()]);

    // Warm one entry so the shard document has something to count.
    let mut rc = WireClient::connect(&replica).unwrap();
    rc.predict_graph(&Family::ResNet.generate(0)).unwrap();

    let shard = Json::parse(&rc.shard_stats().unwrap()).unwrap();
    assert_eq!(shard.path(&["ok"]).as_bool(), Some(true));
    let owned: usize = shard
        .path(&["cache_shard_keys"])
        .as_arr()
        .unwrap()
        .iter()
        .map(|n| n.as_usize().unwrap())
        .sum();
    assert_eq!(Some(owned), shard.path(&["entries"]).as_usize());
    // Per-shard ownership also rides along in the full stats document.
    let full = Json::parse(&rc.stats().unwrap()).unwrap();
    assert!(full.path(&["cache_shard_keys"]).as_arr().is_some());

    // A plain replica does not serve fleet_stats...
    let err = rc.fleet_stats().unwrap_err().to_string();
    assert!(err.contains("fleet router"), "unexpected error: {err}");
    // ...and one without a store has no manifest to replicate.
    let err = rc.fetch_manifest().unwrap_err().to_string();
    assert!(err.contains("cache store"), "unexpected error: {err}");

    // The router answers both stats verbs with the fleet document...
    let mut fc = WireClient::connect(&router_addr).unwrap();
    for doc in [fc.fleet_stats().unwrap(), fc.stats().unwrap()] {
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.path(&["fleet"]).as_str(), Some("router"));
        assert_eq!(v.path(&["replicas"]).as_usize(), Some(1));
        let row = &v.path(&["replica_stats"]).as_arr().unwrap()[0];
        assert_eq!(row.path(&["addr"]).as_str(), Some(replica.as_str()));
        assert_eq!(row.path(&["ring_position"]).as_str().map(str::len), Some(16));
    }
    // ...and points replication verbs at the replicas.
    let err = fc.shard_stats().unwrap_err().to_string();
    assert!(err.contains("replicas"), "unexpected error: {err}");
}

// ------------------------------------------------------------- failover --

/// Acceptance: kill one of three replica processes mid-stream; rerunning
/// the same stream through the same client connection sees zero errors
/// (the dead shard fails over), identical predictions, and `fleet_stats`
/// records the failovers + the death.
#[test]
fn killed_replica_fails_over_without_client_errors() {
    let children: Vec<ChildReplica> = (0..3).map(|_| ChildReplica::spawn(&[])).collect();
    let router_addr = start_router(children.iter().map(|c| c.addr.clone()).collect());
    let mut client = WireClient::connect(&router_addr).unwrap();

    let graphs = request_stream(0..2);
    let first: Vec<Prediction> = graphs
        .iter()
        .map(|g| client.predict_graph(g).unwrap())
        .collect();

    let mut children = children;
    let dead_addr = children[0].addr.clone();
    children[0].kill();

    for (g, want) in graphs.iter().zip(&first) {
        let got = client
            .predict_graph(g)
            .expect("failover must hide the dead replica from clients");
        assert_eq!(&got, want, "prediction changed after failover");
    }

    // Let the health prober catch the corpse even if no request did.
    std::thread::sleep(Duration::from_millis(800));
    let stats = Json::parse(&client.fleet_stats().unwrap()).unwrap();
    let rows = stats.path(&["replica_stats"]).as_arr().unwrap();
    let dead = rows
        .iter()
        .find(|r| r.path(&["addr"]).as_str() == Some(dead_addr.as_str()))
        .expect("dead replica still listed");
    assert_eq!(dead.path(&["alive"]).as_bool(), Some(false), "{stats}");
    assert_eq!(stats.path(&["alive"]).as_usize(), Some(2), "{stats}");
    let failed_over: usize = rows
        .iter()
        .map(|r| r.path(&["failed_over"]).as_usize().unwrap())
        .sum();
    assert!(failed_over > 0, "no request recorded a failover: {stats}");
}

// ---------------------------------------------------------------- sweeps --

/// Acceptance: a sweep routed through the fleet lands on the replica
/// whose ring slice owns the *base* graph's fingerprint (verb-level
/// routing for the multi-frame exchange), and the streamed results match
/// a direct sweep on a single coordinator.
#[test]
fn sweep_routes_to_the_base_fingerprint_owner() {
    let coords: Vec<Arc<Coordinator>> = (0..3).map(|_| sim_coordinator()).collect();
    let replicas: Vec<String> = coords.iter().map(|c| start_reactor(c.clone())).collect();
    let router_addr = start_router(replicas);
    let mut client = WireClient::connect(&router_addr).unwrap();

    let base = Family::ResNet.generate(1);
    let spec = SweepSpec {
        widths: vec![100, 50],
        dtypes: vec![DType::F32, DType::F16],
        ..SweepSpec::default()
    };
    let (items, summary) = client.sweep(&base, None, &spec).unwrap();
    assert_eq!(items.len(), 4);
    assert_eq!(summary.candidates, 4);
    assert!(items.iter().all(|i| i.result.is_ok()), "{items:?}");
    assert!(!summary.frontier.is_empty());

    // The whole grid lands on the base fingerprint's ring owner; the
    // other replicas never see the sweep.
    let key = CacheKey::new(CostSweep::of(&base).fingerprint, &Target::default());
    let ring = HashRing::new(3, RouterConfig::default().vnodes);
    let owner = ring.owner(key.as_u128());
    for (i, c) in coords.iter().enumerate() {
        let got = c.metrics().sweeps;
        assert_eq!(
            got,
            u64::from(i == owner),
            "replica {i} served {got} sweeps (owner is {owner})"
        );
    }

    // Parity with a direct single-coordinator sweep of the same grid.
    let reference = sim_coordinator();
    let mut want: Vec<SweepItem> = Vec::new();
    reference
        .run_sweep(&base, &spec, &Target::default(), &mut |ev| {
            if let SweepEvent::Chunk(c) = ev {
                want.extend(c);
            }
            true
        })
        .unwrap();
    assert_eq!(want.len(), items.len());
    for (w, g) in want.iter().zip(&items) {
        assert_eq!(w.index, g.index);
        assert_eq!(w.label, g.label);
        assert_eq!(
            w.result.as_ref().unwrap().latency_ms,
            g.result.as_ref().unwrap().latency_ms,
            "sweep item {} diverged through the router",
            g.label
        );
    }
}

/// Acceptance: the replica owning a sweep dies; re-issuing the sweep on
/// the same client connection sees a complete, duplicate-free stream and
/// no client-visible error (the router discovers the death inside the
/// exchange and fails over), and `fleet_stats` records the failover.
#[test]
fn sweep_fails_over_when_the_owner_dies() {
    let children: Vec<ChildReplica> = (0..2).map(|_| ChildReplica::spawn(&[])).collect();
    let router_addr = start_router(children.iter().map(|c| c.addr.clone()).collect());
    let mut client = WireClient::connect(&router_addr).unwrap();

    let base = Family::Vgg.generate(2);
    let spec = SweepSpec {
        depths: vec![1, 2],
        batches: vec![1, 4],
        ..SweepSpec::default()
    };
    let (first_items, first) = client.sweep(&base, None, &spec).unwrap();
    assert_eq!(first.candidates, 4);
    assert_eq!(first.errors, 0);

    // With only sweep traffic, exactly one replica routed: the owner.
    let stats = Json::parse(&client.fleet_stats().unwrap()).unwrap();
    let owner_addr = stats
        .path(&["replica_stats"])
        .as_arr()
        .unwrap()
        .iter()
        .find(|r| r.path(&["routed"]).as_usize().unwrap() > 0)
        .and_then(|r| r.path(&["addr"]).as_str())
        .expect("one replica owns the sweep")
        .to_string();
    let mut children = children;
    children
        .iter_mut()
        .find(|c| c.addr == owner_addr)
        .expect("owner is one of the children")
        .kill();

    let (again_items, again) = client
        .sweep(&base, None, &spec)
        .expect("sweep failover must hide the dead replica from clients");
    assert_eq!(again.candidates, 4);
    assert_eq!(again.errors, 0);
    let mut idx: Vec<u32> = again_items.iter().map(|i| i.index).collect();
    idx.sort_unstable();
    idx.dedup();
    assert_eq!(idx.len(), 4, "duplicate or missing items after failover");
    for (a, b) in first_items.iter().zip(&again_items) {
        assert_eq!(
            a.result.as_ref().unwrap().latency_ms,
            b.result.as_ref().unwrap().latency_ms,
            "prediction changed after sweep failover"
        );
    }
    let stats = Json::parse(&client.fleet_stats().unwrap()).unwrap();
    let failed: usize = stats
        .path(&["replica_stats"])
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.path(&["failed_over"]).as_usize().unwrap())
        .sum();
    assert!(failed > 0, "no failover recorded: {stats}");
}

// ----------------------------------------------------------- warm start --

/// Acceptance: a cold coordinator warm-starts from a peer's committed
/// manifest over the wire — `warm_start_entries > 0` and every imported
/// prediction is served without a single backend batch (no recompute).
#[test]
fn replica_warm_starts_from_peer_manifest() {
    let src_store = fleet_dir("warm-src");
    let scratch = fleet_dir("warm-scratch");
    let source = sim_coordinator_with_store(&src_store);

    let graphs: Vec<Graph> = ALL_FAMILIES.iter().map(|f| f.generate(7)).collect();
    let want: Vec<Prediction> = graphs
        .iter()
        .map(|g| source.predict_to(g.clone(), None).unwrap())
        .collect();
    // Replication ships committed generations only: compact first.
    let compact = source.compact_cache().unwrap();
    assert_eq!(compact.entries, graphs.len());
    let src_addr = start_reactor(source);

    let report = replicate_from_peer(&src_addr, &scratch).unwrap();
    assert_eq!(report.generation, compact.generation);
    assert!(report.shards_written > 0 && report.bytes > 0);

    let warm = sim_coordinator();
    let load = warm.load_cache(Some(scratch.to_str().unwrap())).unwrap();
    assert_eq!(load.entries, graphs.len());
    assert_eq!(warm.metrics().warm_start_entries as usize, graphs.len());

    for (g, w) in graphs.iter().zip(&want) {
        assert_eq!(&warm.predict_to(g.clone(), None).unwrap(), w);
    }
    let m = warm.metrics();
    assert_eq!(m.batches, 0, "warm replica recomputed imported entries");
    assert!(m.cache_hits as usize >= graphs.len());

    let _ = std::fs::remove_dir_all(&src_store);
    let _ = std::fs::remove_dir_all(&scratch);
}

/// The CLI path end-to-end: `serve --fleet replica --fleet-warm-from`
/// fetches the peer's store before binding, reports the warm start in
/// `cache_stats`, and serves the peer's predictions as pure cache hits.
#[test]
fn cli_replica_warm_starts_over_the_wire() {
    let src_store = fleet_dir("cli-warm-src");
    let source = sim_coordinator_with_store(&src_store);
    let g = Family::MobileNet.generate(3);
    let want = source.predict_to(g.clone(), None).unwrap();
    source.compact_cache().unwrap();
    let src_addr = start_reactor(source);

    let mut child = ChildReplica::spawn(&["--fleet-warm-from", &src_addr]);
    let mut client = WireClient::connect(&child.addr).unwrap();
    let stats = Json::parse(&client.stats().unwrap()).unwrap();
    let warm = stats.path(&["warm_start_entries"]).as_usize().unwrap();
    assert!(warm > 0, "child replica served cold: {stats}");

    assert_eq!(client.predict_graph(&g).unwrap(), want);
    let stats = Json::parse(&client.stats().unwrap()).unwrap();
    assert_eq!(stats.path(&["batches"]).as_usize(), Some(0), "{stats}");
    assert!(stats.path(&["hits"]).as_usize().unwrap() >= 1, "{stats}");

    child.kill();
    let _ = std::fs::remove_dir_all(&src_store);
}
