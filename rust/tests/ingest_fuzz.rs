//! Frontend ingestion fuzz & property suite.
//!
//! Three invariants, over the whole modelgen zoo:
//!
//! 1. **Round-trip parity** — binary ONNX and safetensors exports parse
//!    back to the structure (and dtypes) they encoded.
//! 2. **Error, never panic** — mutated, truncated, and bit-flipped model
//!    bytes must always produce a `Result`, for every frontend. A panic
//!    anywhere in the parse path fails this suite.
//! 3. **fp32 bit-identity** — dtype plumbing must not move a single bit
//!    for default-dtype graphs: fingerprints, statics, and measurements
//!    of an fp32 graph are identical before and after a trip through the
//!    dtype-aware frontends and the quantize pass.
//!
//! Seeded like `cache_journal.rs`: set `DIPPM_PROPTEST_SEED` to reproduce
//! a CI failure exactly.

use dippm::frontends::{
    self, export_bytes, parse_bytes_any, parse_framework_bytes, structurally_equal, Framework,
};
use dippm::ir::quantize::{dtype_sweep, quantize};
use dippm::ir::{DType, Graph, ALL_DTYPES};
use dippm::modelgen::{Family, ALL_FAMILIES};
use dippm::simulator::{Fingerprint, GraphAnalysis, Simulator};
use dippm::util::proptest::{proptest, Gen};
use dippm::{prop_assert, prop_assert_eq};

fn zoo_graph(g: &mut Gen) -> Graph {
    let family = *g.rng.choose(&ALL_FAMILIES);
    let idx = g.usize_in(0, family.grid_size().saturating_sub(1));
    family.generate(idx)
}

// ---------------------------------------------------------------------------
// 1. Round-trip parity
// ---------------------------------------------------------------------------

#[test]
fn onnx_pb_roundtrips_the_whole_zoo() {
    for family in ALL_FAMILIES {
        let g = family.generate(1);
        let parsed = frontends::onnx_pb::parse(&frontends::onnx_pb::export(&g))
            .unwrap_or_else(|e| panic!("{family:?}: {e}"));
        assert!(
            structurally_equal(&g, &parsed),
            "{family:?} altered through binary ONNX"
        );
        assert_eq!(parsed.family, g.family, "{family:?}");
        assert_eq!(parsed.batch, g.batch, "{family:?}");
    }
}

#[test]
fn safetensors_roundtrips_weighted_structure_across_zoo() {
    let weighted = |g: &Graph| {
        g.nodes
            .iter()
            .filter(|n| n.op.counts_macs() && !n.inputs.is_empty())
            .count()
    };
    for family in ALL_FAMILIES {
        let g = family.generate(0);
        let parsed = frontends::safetensors::parse(&frontends::safetensors::export(&g))
            .unwrap_or_else(|e| panic!("{family:?}: {e}"));
        // Conv/dense branches survive; batch_matmul has no weight tensor.
        let matmuls = g
            .nodes
            .iter()
            .filter(|n| n.op == dippm::ir::OpKind::BatchMatmul)
            .count();
        assert_eq!(
            weighted(&parsed),
            weighted(&g) - matmuls,
            "{family:?} lost weighted ops through safetensors"
        );
        assert_eq!(parsed.batch, g.batch, "{family:?}");
        assert_eq!(parsed.family, g.family, "{family:?}");
    }
}

#[test]
fn dtype_survives_binary_roundtrips_for_every_dtype() {
    let g = Family::MobileNet.generate(3);
    for variant in dtype_sweep(&g) {
        let dt = variant.nodes[0].attrs.dtype;
        let pb = frontends::onnx_pb::parse(&frontends::onnx_pb::export(&variant)).unwrap();
        assert!(structurally_equal(&variant, &pb), "{dt}");
        assert!(pb.nodes.iter().all(|n| n.attrs.dtype == dt), "{dt}");
        let st = frontends::safetensors::parse(&frontends::safetensors::export(&variant)).unwrap();
        assert!(st.nodes.iter().all(|n| n.attrs.dtype == dt), "{dt}");
    }
}

// ---------------------------------------------------------------------------
// 2. Error, never panic
// ---------------------------------------------------------------------------

/// Apply one random corruption: truncate, flip bytes, splice a hostile
/// varint/length, or zero a window.
fn mutate(g: &mut Gen, bytes: &mut Vec<u8>) {
    if bytes.is_empty() {
        bytes.extend_from_slice(&[0xFF, 0xFF, 0xFF]);
        return;
    }
    match g.usize_in(0, 3) {
        0 => {
            let at = g.usize_in(0, bytes.len() - 1);
            bytes.truncate(at);
        }
        1 => {
            for _ in 0..=g.usize_in(0, 7) {
                let at = g.usize_in(0, bytes.len() - 1);
                bytes[at] ^= 1 << g.usize_in(0, 7);
            }
        }
        2 => {
            // Hostile varint: max-length, all-continuation bytes.
            let at = g.usize_in(0, bytes.len() - 1);
            for (i, b) in bytes[at..].iter_mut().take(10).enumerate() {
                *b = if i == 9 { 0x7F } else { 0xFF };
            }
        }
        _ => {
            let at = g.usize_in(0, bytes.len() - 1);
            let end = (at + g.usize_in(1, 64)).min(bytes.len());
            for b in &mut bytes[at..end] {
                *b = 0;
            }
        }
    }
}

#[test]
fn mutated_onnx_pb_errors_never_panic() {
    proptest(60, |g| {
        let graph = zoo_graph(g);
        let mut bytes = frontends::onnx_pb::export(&graph);
        for _ in 0..=g.usize_in(0, 2) {
            mutate(g, &mut bytes);
        }
        // Any Result is acceptable; a panic aborts the whole suite. An Ok
        // must have come through assemble → validate.
        if let Ok(parsed) = frontends::onnx_pb::parse(&bytes) {
            prop_assert!(parsed.validate().is_ok(), "parsed graph fails validate");
        }
        Ok(())
    });
}

#[test]
fn mutated_safetensors_errors_never_panic() {
    proptest(60, |g| {
        let graph = zoo_graph(g);
        let mut bytes = frontends::safetensors::export(&graph);
        for _ in 0..=g.usize_in(0, 2) {
            mutate(g, &mut bytes);
        }
        if let Ok(parsed) = frontends::safetensors::parse(&bytes) {
            prop_assert!(parsed.validate().is_ok(), "parsed graph fails validate");
        }
        Ok(())
    });
}

#[test]
fn mutated_text_formats_error_never_panic() {
    // Text frontends get the same treatment through the byte entry point:
    // mutations may break UTF-8, detection, or structure — never the process.
    let frameworks = [
        Framework::Native,
        Framework::PyTorch,
        Framework::TensorFlow,
        Framework::Onnx,
        Framework::Paddle,
    ];
    proptest(60, |g| {
        let graph = zoo_graph(g);
        let fw = frameworks[g.usize_in(0, frameworks.len() - 1)];
        let mut bytes = export_bytes(fw, &graph);
        for _ in 0..=g.usize_in(0, 2) {
            mutate(g, &mut bytes);
        }
        if let Ok(parsed) = parse_framework_bytes(fw, &bytes) {
            prop_assert!(parsed.validate().is_ok(), "parsed graph fails validate");
        }
        let _ = parse_bytes_any(&bytes); // auto-detect path too
        Ok(())
    });
}

#[test]
fn non_utf8_text_input_is_a_clean_error() {
    let junk = [0xC3, 0x28, 0xFF, 0xFE]; // invalid UTF-8 sequences
    for fw in [Framework::Onnx, Framework::Native, Framework::PyTorch] {
        let err = parse_framework_bytes(fw, &junk).unwrap_err();
        assert!(err.contains("UTF-8"), "{fw:?}: {err}");
    }
}

// ---------------------------------------------------------------------------
// 3. fp32 bit-identity under the dtype machinery
// ---------------------------------------------------------------------------

#[test]
fn fp32_graphs_are_bit_identical_through_dtype_plumbing() {
    let sim = Simulator::new();
    proptest(25, |g| {
        let graph = zoo_graph(g);
        let before = GraphAnalysis::of(&graph);

        // The quantize pass at F32 is the identity.
        let q = quantize(&graph, DType::F32);
        prop_assert_eq!(&graph, &q);

        // A trip through the dtype-aware binary frontend moves no bits.
        let back = frontends::onnx_pb::parse(&frontends::onnx_pb::export(&graph))
            .map_err(|e| format!("pb roundtrip: {e}"))?;
        let after = GraphAnalysis::of(&back);
        prop_assert_eq!(before.fingerprint, after.fingerprint);
        prop_assert_eq!(before.statics, after.statics);

        let m0 = sim.measure(&graph);
        let m1 = sim.measure(&back);
        prop_assert_eq!(m0.latency_ms.to_bits(), m1.latency_ms.to_bits());
        prop_assert_eq!(m0.memory_mb.to_bits(), m1.memory_mb.to_bits());
        Ok(())
    });
}

#[test]
fn dtype_variants_get_distinct_fingerprints_and_cheaper_costs() {
    let sim = Simulator::new();
    let g = Family::ResNet.generate(4);
    let prints: Vec<Fingerprint> = dtype_sweep(&g)
        .iter()
        .map(Fingerprint::of_graph)
        .collect();
    for i in 0..prints.len() {
        for j in i + 1..prints.len() {
            assert_ne!(prints[i], prints[j], "dtypes {i} and {j} collide");
        }
    }
    let base = sim.measure(&g);
    for dt in [DType::F16, DType::BF16, DType::I8] {
        let m = sim.measure(&quantize(&g, dt));
        assert!(m.latency_ms < base.latency_ms, "{dt} not faster");
        assert!(m.memory_mb < base.memory_mb, "{dt} not smaller");
    }
    assert_eq!(ALL_DTYPES.len(), prints.len());
}
