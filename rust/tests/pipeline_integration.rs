//! Integration: cross-module pipelines that don't need PJRT — dataset
//! build → save → load → batch assembly; frontends → featurization parity;
//! simulator ground truth sanity against known model scales.

use dippm::dataset::{io as ds_io, Dataset};
use dippm::features::{encode_graph, static_features};
use dippm::frontends::{self, Framework};
use dippm::modelgen::{Family, ALL_FAMILIES};
use dippm::simulator::{MigProfile, Simulator};

#[test]
fn dataset_save_load_then_featurize() {
    let ds = Dataset::build(0.005, 21, 4);
    let path = std::env::temp_dir().join("dippm_pipeline_ds.bin");
    let path = path.to_str().unwrap().to_string();
    ds_io::save(&path, &ds).unwrap();
    let loaded = ds_io::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(ds.len(), loaded.len());
    // Features computed from reloaded graphs are identical.
    for (a, b) in ds.samples.iter().zip(&loaded.samples).take(20) {
        let fa = encode_graph(&a.graph);
        let fb = encode_graph(&b.graph);
        assert_eq!(fa.x, fb.x);
        assert_eq!(fa.a_hat, fb.a_hat);
    }
}

#[test]
fn features_identical_across_frontend_paths() {
    // The NFG must produce the same X/Â whether the graph came from
    // modelgen directly or through any framework round-trip — this is the
    // paper's framework-agnosticism claim at the feature level.
    for family in [Family::ResNet, Family::Swin, Family::MobileNet] {
        let g = family.generate(2);
        let direct = encode_graph(&g);
        let s_direct = static_features(&g);
        for fw in [
            Framework::Native,
            Framework::PyTorch,
            Framework::TensorFlow,
            Framework::Onnx,
            Framework::Paddle,
        ] {
            let rt = frontends::parse(fw, &frontends::export(fw, &g)).unwrap();
            let via = encode_graph(&rt);
            assert_eq!(direct.x, via.x, "{family:?} via {fw:?}");
            assert_eq!(direct.a_hat, via.a_hat, "{family:?} via {fw:?}");
            assert_eq!(s_direct, static_features(&rt), "{family:?} via {fw:?}");
        }
    }
}

#[test]
fn simulator_scales_match_known_model_ordering() {
    // Coarse sanity on the ground-truth substrate: a VGG-style model is
    // slower per image than a MobileNet at the same batch/resolution.
    let sim = Simulator::new();
    // vgg16-w64 @224 b32 (grid: vi=8, ri=2, bi=5) vs mobilenetv2-w1.0 @224
    // b32 (vi=4, ri=3, bi=5): ~15.5 GFLOP/img vs ~0.3 GFLOP/img.
    let vgg = Family::Vgg.generate(8 * 32 + 2 * 8 + 5);
    let mobile = Family::MobileNet.generate(4 * 40 + 3 * 8 + 5);
    assert!(vgg.variant.starts_with("vgg16-w64"), "{}", vgg.variant);
    assert_eq!(vgg.batch, 32);
    assert_eq!(mobile.batch, 32);
    let lat_vgg = sim.latency_s(&vgg, MigProfile::G7_40) / vgg.batch as f64;
    let lat_mob = sim.latency_s(&mobile, MigProfile::G7_40) / mobile.batch as f64;
    assert!(
        lat_vgg > lat_mob,
        "vgg {lat_vgg} should out-cost mobilenet {lat_mob}"
    );
}

#[test]
fn dataset_targets_vary_across_families() {
    // The learning problem is non-degenerate: different families produce
    // clearly different target scales.
    let ds = Dataset::build(0.004, 5, 4);
    let mut lat_by_family: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for s in &ds.samples {
        lat_by_family
            .entry(Box::leak(s.graph.family.clone().into_boxed_str()))
            .or_default()
            .push(s.y.latency_ms);
    }
    let means: Vec<f64> = lat_by_family
        .values()
        .map(|v| v.iter().sum::<f64>() / v.len() as f64)
        .collect();
    let max = means.iter().cloned().fold(0.0, f64::max);
    let min = means.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(max / min > 1.5, "family latencies too uniform: {means:?}");
}

#[test]
fn every_family_exports_to_every_framework() {
    for family in ALL_FAMILIES {
        let g = family.generate(0);
        for fw in [
            Framework::Native,
            Framework::PyTorch,
            Framework::TensorFlow,
            Framework::Onnx,
            Framework::Paddle,
        ] {
            let text = frontends::export(fw, &g);
            assert!(text.len() > 100, "{family:?} -> {fw:?} export too small");
            assert_eq!(frontends::detect(&text), Some(fw));
        }
    }
}

#[test]
fn batch_vs_latency_crossover_shape() {
    // Throughput rises with batch while per-request latency rises too —
    // the design-space-exploration story from the paper's intro.
    let sim = Simulator::new();
    let mut last_lat = 0.0;
    let mut last_thru = 0.0;
    for (i, batch) in [1usize, 8, 64].iter().enumerate() {
        let mut b = dippm::ir::GraphBuilder::new("t", &format!("dse-b{batch}"), *batch);
        let x = b.input(vec![*batch, 3, 128, 128]);
        let mut h = b.conv_relu(x, 32, 3, 2, 1);
        for _ in 0..4 {
            h = b.conv_relu(h, 32, 3, 1, 1);
        }
        let g = b.finish();
        let lat = sim.latency_s(&g, MigProfile::G7_40);
        let thru = *batch as f64 / lat;
        if i > 0 {
            assert!(lat > last_lat, "latency must grow with batch");
            assert!(thru > last_thru, "throughput must grow with batch here");
        }
        last_lat = lat;
        last_thru = thru;
    }
}
