//! Integration: the binary wire protocol — frame codec properties, the
//! nonblocking reactor end-to-end (predictions must match the coordinator
//! exactly), hostile-input handling (garbage, torn length prefixes, bad
//! checksums → one seq-0 error frame, then close), pipelining with
//! out-of-order replies matched by sequence id, connection caps and idle
//! timeouts on both listeners, and the transport counters in `cache_stats`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use dippm::cache::Target;
use dippm::coordinator::{
    tcp, Backend, Coordinator, CoordinatorOptions, PredictRequest, RawOutcome, ServeOptions,
};
use dippm::frontends;
use dippm::modelgen::{Family, ALL_FAMILIES};
use dippm::util::json::Json;
use dippm::util::proptest::proptest;
use dippm::wire::frame::{self, Decoded, FrameKind, DEFAULT_MAX_PAYLOAD};
use dippm::wire::{codec, reactor, Frame, ReactorConfig, WireClient};
use dippm::{prop_assert, prop_assert_eq};

fn sim_coordinator() -> Arc<Coordinator> {
    Arc::new(Coordinator::start_sim(CoordinatorOptions::default()).unwrap())
}

/// Start the binary reactor on an ephemeral port; returns its address.
fn start_reactor(coord: Arc<Coordinator>, cfg: ReactorConfig) -> String {
    let (port_tx, port_rx) = mpsc::channel();
    std::thread::spawn(move || {
        reactor::serve(coord, "127.0.0.1:0", cfg, move |p| {
            let _ = port_tx.send(p);
        })
        .unwrap();
    });
    format!("127.0.0.1:{}", port_rx.recv().unwrap())
}

/// Start the JSON-lines listener on an ephemeral port; returns its address.
fn start_json(coord: Arc<Coordinator>, opts: ServeOptions) -> String {
    let (port_tx, port_rx) = mpsc::channel();
    std::thread::spawn(move || {
        tcp::serve_with(coord, "127.0.0.1:0", opts, move |p| {
            let _ = port_tx.send(p);
        })
        .unwrap();
    });
    format!("127.0.0.1:{}", port_rx.recv().unwrap())
}

/// Raw socket speaking hand-crafted bytes — for hostile-input tests the
/// well-behaved `WireClient` cannot express.
struct RawWire {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl RawWire {
    fn connect(addr: &str) -> RawWire {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        RawWire {
            stream,
            buf: Vec::new(),
        }
    }

    fn send(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).unwrap();
    }

    /// Block until one complete (well-formed) frame arrives.
    fn read_frame(&mut self) -> Frame {
        let mut chunk = [0u8; 4096];
        loop {
            match frame::decode(&self.buf, DEFAULT_MAX_PAYLOAD).unwrap() {
                Decoded::Frame {
                    kind,
                    seq,
                    payload,
                    consumed,
                } => {
                    let f = Frame {
                        kind,
                        seq,
                        payload: payload.to_vec(),
                    };
                    self.buf.drain(..consumed);
                    return f;
                }
                Decoded::Incomplete => {
                    let n = self.stream.read(&mut chunk).expect("frame before timeout");
                    assert!(n > 0, "connection closed before a full frame arrived");
                    self.buf.extend_from_slice(&chunk[..n]);
                }
            }
        }
    }

    /// Assert the server closes the connection (EOF within the timeout).
    fn expect_closed(&mut self) {
        let mut chunk = [0u8; 4096];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => return,
                Ok(_) => continue, // drain whatever was still in flight
                Err(e) => panic!("expected EOF, got read error: {e}"),
            }
        }
    }
}

// ---------------------------------------------------------------- codec --

#[test]
fn frame_roundtrip_property() {
    const KINDS: [FrameKind; 4] = [
        FrameKind::Request,
        FrameKind::Response,
        FrameKind::Error,
        FrameKind::Stats,
    ];
    proptest(200, |g| {
        let kind = KINDS[g.usize_in(0, KINDS.len() - 1)];
        let seq = g.usize_in(0, u32::MAX as usize) as u32;
        let payload: Vec<u8> = g
            .vec_usize(512, 255)
            .into_iter()
            .map(|b| b as u8)
            .collect();
        let bytes = frame::encode(kind, seq, &payload);

        // Full buffer decodes back to exactly what went in.
        match frame::decode(&bytes, DEFAULT_MAX_PAYLOAD).map_err(|e| e.to_string())? {
            Decoded::Frame {
                kind: k,
                seq: s,
                payload: p,
                consumed,
            } => {
                prop_assert_eq!(k, kind);
                prop_assert_eq!(s, seq);
                prop_assert!(p == &payload[..], "payload mismatch");
                prop_assert_eq!(consumed, bytes.len());
            }
            Decoded::Incomplete => return Err("complete frame decoded Incomplete".into()),
        }

        // Every strict prefix is Incomplete — a torn frame is never an
        // error, it just waits for more bytes.
        for cut in 0..bytes.len() {
            let d = frame::decode(&bytes[..cut], DEFAULT_MAX_PAYLOAD)
                .map_err(|e| format!("cut at {cut}: {e}"))?;
            prop_assert!(d == Decoded::Incomplete, "cut at {} not Incomplete", cut);
        }

        // Two pipelined frames decode in order from one buffer.
        let mut two = bytes.clone();
        frame::encode_into(FrameKind::Stats, seq.wrapping_add(1), b"x", &mut two);
        let Ok(Decoded::Frame { consumed, .. }) = frame::decode(&two, DEFAULT_MAX_PAYLOAD) else {
            return Err("first pipelined frame did not decode".into());
        };
        match frame::decode(&two[consumed..], DEFAULT_MAX_PAYLOAD) {
            Ok(Decoded::Frame { seq: s2, .. }) => prop_assert_eq!(s2, seq.wrapping_add(1)),
            other => return Err(format!("second pipelined frame: {other:?}")),
        }
        Ok(())
    });
}

#[test]
fn request_codec_roundtrip_property() {
    proptest(40, |g| {
        let fam = ALL_FAMILIES[g.usize_in(0, ALL_FAMILIES.len() - 1)];
        let graph = fam.generate(g.usize_in(0, 6));
        let target = if g.bool() { Some("a100:2g.10gb") } else { None };
        let payload = codec::encode_request(&graph, target);
        let (back, t, deadline) = codec::decode_request(&payload)?;
        prop_assert_eq!(deadline, None);
        prop_assert!(
            frontends::structurally_equal(&graph, &back),
            "decoded graph differs structurally ({})",
            graph.variant
        );
        prop_assert_eq!(t.is_some(), target.is_some());
        Ok(())
    });
}

// --------------------------------------------------------- happy path ---

#[test]
fn binary_predictions_match_the_coordinator_exactly() {
    let coord = sim_coordinator();
    let addr = start_reactor(coord.clone(), ReactorConfig::default());
    let mut client = WireClient::connect(&addr).unwrap();

    for (i, family) in [Family::Mlp, Family::ResNet, Family::Vit]
        .into_iter()
        .enumerate()
    {
        let g = family.generate(i);
        let want = coord.predict(g.clone()).unwrap();
        let got = client.predict_graph(&g).unwrap();
        assert_eq!(got, want, "binary path changed the answer for {}", g.variant);
    }

    // A target string rides the wire and selects the same MIG-sliced entry.
    let g = Family::MobileNet.generate(1);
    let target = Target::parse("a100:2g.10gb").unwrap();
    let want = coord.predict_to(g.clone(), Some(target)).unwrap();
    let got = client.predict_graph_on(&g, "a100:2g.10gb").unwrap();
    assert_eq!(got, want);
}

#[test]
fn request_error_echoes_seq_and_keeps_the_connection_open() {
    let coord = sim_coordinator();
    let addr = start_reactor(coord, ReactorConfig::default());
    let mut client = WireClient::connect(&addr).unwrap();
    let g = Family::Mlp.generate(0);

    let bad_seq = client.send_predict(&g, Some("a100:9g.99gb")).unwrap();
    let (seq, reply) = client.recv_reply().unwrap();
    assert_eq!(seq, bad_seq, "request-level errors echo the request seq");
    assert!(reply.is_err(), "unknown MIG profile must be an error");

    // The connection survives a request-level error.
    let pred = client.predict_graph(&g).unwrap();
    assert!(pred.latency_ms.is_finite());
}

// ------------------------------------------------------ hostile input ---

#[test]
fn json_bytes_on_the_binary_port_get_one_error_frame_then_close() {
    let coord = sim_coordinator();
    let addr = start_reactor(coord, ReactorConfig::default());
    let mut raw = RawWire::connect(&addr);
    raw.send(b"{\"cmd\":\"cache_stats\"}\n");
    let f = raw.read_frame();
    assert_eq!(f.kind, FrameKind::Error);
    assert_eq!(f.seq, 0, "framing errors carry seq 0");
    let msg = String::from_utf8_lossy(&f.payload).into_owned();
    assert!(msg.contains("magic"), "unhelpful error: {msg}");
    raw.expect_closed();
}

#[test]
fn corrupt_checksum_gets_one_error_frame_then_close() {
    let coord = sim_coordinator();
    let addr = start_reactor(coord, ReactorConfig::default());
    let mut raw = RawWire::connect(&addr);

    let payload = codec::encode_request(&Family::Mlp.generate(0), None);
    let mut bytes = frame::encode(FrameKind::Request, 9, &payload);
    let last = bytes.len() - 1;
    bytes[last] ^= 0xff;
    raw.send(&bytes);

    let f = raw.read_frame();
    assert_eq!(f.kind, FrameKind::Error);
    assert_eq!(f.seq, 0);
    let msg = String::from_utf8_lossy(&f.payload).into_owned();
    assert!(msg.contains("checksum"), "unhelpful error: {msg}");
    raw.expect_closed();
}

#[test]
fn oversized_length_prefix_is_rejected_before_buffering() {
    let coord = sim_coordinator();
    let addr = start_reactor(coord, ReactorConfig::default());
    let mut raw = RawWire::connect(&addr);

    // A 20-byte header claiming a payload one past the limit: rejected on
    // the header alone, no payload bytes ever sent.
    let mut header = Vec::new();
    header.extend_from_slice(&frame::MAGIC);
    header.push(frame::WIRE_VERSION);
    header.push(FrameKind::Request.as_u8());
    header.extend_from_slice(&7u32.to_le_bytes());
    header.extend_from_slice(&(DEFAULT_MAX_PAYLOAD as u32 + 1).to_le_bytes());
    header.extend_from_slice(&0u64.to_le_bytes());
    raw.send(&header);

    let f = raw.read_frame();
    assert_eq!(f.kind, FrameKind::Error);
    assert_eq!(f.seq, 0);
    let msg = String::from_utf8_lossy(&f.payload).into_owned();
    assert!(msg.contains("exceeds"), "unhelpful error: {msg}");
    raw.expect_closed();
}

// --------------------------------------------------------- pipelining ---

/// A backend whose every call waits for the gate: lets a test park a cache
/// miss inside the executor while cache hits keep flowing.
struct GateBackend {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl Backend for GateBackend {
    fn name(&self) -> &'static str {
        "gate"
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn predict_into(
        &mut self,
        requests: &[PredictRequest<'_>],
        out: &mut Vec<RawOutcome>,
    ) -> anyhow::Result<()> {
        {
            let (open, cv) = &*self.gate;
            let mut open = open.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        }
        out.extend(
            requests
                .iter()
                .map(|req| Ok([1.0, 100.0 + req.graph.n_nodes() as f64, 1.0])),
        );
        Ok(())
    }
}

#[test]
fn pipelined_replies_can_arrive_out_of_order_matched_by_seq() {
    let gate = Arc::new((Mutex::new(true), Condvar::new()));
    let coord = {
        let gate = gate.clone();
        Arc::new(
            Coordinator::start_with_backend(
                Box::new(move || {
                    Ok(Box::new(GateBackend { gate: gate.clone() }) as Box<dyn Backend>)
                }),
                CoordinatorOptions::default(),
            )
            .unwrap(),
        )
    };
    let g_hot = Family::Mlp.generate(0);
    let g_cold = Family::ResNet.generate(0);

    // Warm the cache while the gate is open, then shut it: the next miss
    // blocks inside the backend until the test releases it.
    let warm = coord.predict(g_hot.clone()).unwrap();
    *gate.0.lock().unwrap() = false;

    let addr = start_reactor(coord, ReactorConfig::default());
    let mut client = WireClient::connect(&addr).unwrap();
    let seq_cold = client.send_predict(&g_cold, None).unwrap();
    let seq_hot = client.send_predict(&g_hot, None).unwrap();

    // The hot request was sent second but its cache hit overtakes the
    // gated miss — the reply stream is out of order by design.
    let (first_seq, first) = client.recv_reply().unwrap();
    assert_eq!(first_seq, seq_hot, "cache hit must not wait behind the miss");
    assert_eq!(first.unwrap(), warm);

    {
        let (open, cv) = &*gate;
        *open.lock().unwrap() = true;
        cv.notify_all();
    }
    let (second_seq, second) = client.recv_reply().unwrap();
    assert_eq!(second_seq, seq_cold);
    assert!(second.unwrap().latency_ms.is_finite());
}

#[test]
fn reactor_sustains_ten_thousand_pipelined_requests() {
    let coord = sim_coordinator();
    let g = Family::Mlp.generate(0);
    let warm = coord.predict(g.clone()).unwrap();

    let cfg = ReactorConfig {
        event_loops: 2,
        ..ReactorConfig::default()
    };
    let addr = start_reactor(coord, cfg);

    const CONNS: usize = 64;
    const PER_CONN: usize = 160; // 64 * 160 = 10_240 requests

    let mut clients: Vec<WireClient> = (0..CONNS)
        .map(|_| WireClient::connect(&addr).unwrap())
        .collect();

    // Phase 1: pipeline every request on every connection, reading nothing.
    let sent: Vec<Vec<u32>> = clients
        .iter_mut()
        .map(|c| {
            (0..PER_CONN)
                .map(|_| c.send_predict(&g, None).unwrap())
                .collect()
        })
        .collect();

    // Phase 2: collect replies; every connection gets exactly its own seq
    // set back and every prediction is the cached answer.
    for (c, seqs) in clients.iter_mut().zip(&sent) {
        let mut got: Vec<u32> = (0..PER_CONN)
            .map(|_| {
                let (seq, reply) = c.recv_reply().unwrap();
                assert_eq!(reply.unwrap(), warm);
                seq
            })
            .collect();
        got.sort_unstable();
        let mut want = seqs.clone();
        want.sort_unstable();
        assert_eq!(got, want, "reply seqs must cover exactly the sent seqs");
    }

    // Transport counters saw the whole storm.
    let mut stats_client = WireClient::connect(&addr).unwrap();
    let v = Json::parse(&stats_client.stats().unwrap()).unwrap();
    assert!(v.path(&["frames_rx"]).as_usize().unwrap() >= CONNS * PER_CONN);
    assert!(v.path(&["frames_tx"]).as_usize().unwrap() >= CONNS * PER_CONN);
    assert!(v.path(&["connections_accepted"]).as_usize().unwrap() >= CONNS);
    assert!(v.path(&["bytes_rx"]).as_usize().unwrap() > 0);
    assert!(v.path(&["bytes_tx"]).as_usize().unwrap() > 0);
    assert_eq!(v.path(&["frame_decode_errors"]).as_usize(), Some(0));
}

// --------------------------------------------------- caps and hygiene ---

#[test]
fn connection_cap_rejects_the_excess_binary_connection() {
    let coord = sim_coordinator();
    let cfg = ReactorConfig {
        max_connections: 2,
        ..ReactorConfig::default()
    };
    let addr = start_reactor(coord, cfg);
    let g = Family::Mlp.generate(0);

    // Two roundtrips guarantee the accept thread registered both.
    let mut a = WireClient::connect(&addr).unwrap();
    let mut b = WireClient::connect(&addr).unwrap();
    a.predict_graph(&g).unwrap();
    b.predict_graph(&g).unwrap();

    let mut third = RawWire::connect(&addr);
    let f = third.read_frame();
    assert_eq!(f.kind, FrameKind::Error);
    assert_eq!(f.seq, 0);
    assert!(String::from_utf8_lossy(&f.payload).contains("capacity"));
    third.expect_closed();

    let v = Json::parse(&a.stats().unwrap()).unwrap();
    assert!(v.path(&["connections_rejected"]).as_usize().unwrap() >= 1);
    assert_eq!(v.path(&["connections_open"]).as_usize(), Some(2));
}

#[test]
fn connection_cap_rejects_the_excess_json_connection() {
    let coord = sim_coordinator();
    let opts = ServeOptions {
        max_connections: 1,
        ..ServeOptions::default()
    };
    let addr = start_json(coord, opts);

    let mut first = tcp::Client::connect(&addr).unwrap();
    assert!(first.cache_stats().unwrap().contains("\"ok\":true"));

    // Read without writing: the server pushes the rejection line at accept.
    let s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut line = String::new();
    BufReader::new(s).read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("capacity"), "{line}");
}

#[test]
fn idle_binary_connections_are_swept() {
    let coord = sim_coordinator();
    let cfg = ReactorConfig {
        idle_timeout: Duration::from_millis(200),
        ..ReactorConfig::default()
    };
    let addr = start_reactor(coord, cfg);
    let mut raw = RawWire::connect(&addr);
    raw.send(&frame::encode(FrameKind::Stats, 1, &[]));
    assert_eq!(raw.read_frame().kind, FrameKind::Stats);
    // Stay silent past the timeout: the ~1 Hz sweep closes the socket.
    raw.expect_closed();
}

#[test]
fn idle_json_connections_are_closed() {
    let coord = sim_coordinator();
    let opts = ServeOptions {
        idle_timeout: Duration::from_millis(200),
        ..ServeOptions::default()
    };
    let addr = start_json(coord, opts);
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 64];
    let n = s.read(&mut buf).unwrap();
    assert_eq!(n, 0, "idle connection should see clean EOF");
}

// ----------------------------------------------- injection regression ---

#[test]
fn hostile_target_string_is_a_request_error_not_a_command() {
    let coord = sim_coordinator();
    let addr = start_json(coord, ServeOptions::default());
    let mut client = tcp::Client::connect(&addr).unwrap();
    let g = Family::Mlp.generate(0);

    // With the old format!-spliced request line this executed cache_stats.
    let resp = client
        .predict_graph_on(&g, "x\",\"cmd\":\"cache_stats")
        .unwrap();
    assert!(resp.contains("\"ok\":false"), "{resp}");
    assert!(
        !resp.contains("hit_rate"),
        "target injection executed a command: {resp}"
    );
}
