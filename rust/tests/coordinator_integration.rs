//! Integration: the serving coordinator — dynamic batching across threads,
//! TCP JSON-lines protocol, error handling. Uses untrained (init) params:
//! the serving path is identical; only the numbers differ.

use std::sync::Arc;

use dippm::coordinator::{tcp, Coordinator, CoordinatorOptions};
use dippm::frontends::{self, Framework};
use dippm::modelgen::Family;
use dippm::runtime::Runtime;
use dippm::util::json::Json;

fn coordinator() -> Coordinator {
    let rt = Runtime::new("artifacts").expect("run `make artifacts` first");
    let params = rt.init_params("sage", 0).unwrap();
    drop(rt); // the coordinator builds its own runtime in its executor
    Coordinator::start("artifacts", params, CoordinatorOptions::default()).unwrap()
}

#[test]
fn single_predict_roundtrip() {
    let coord = coordinator();
    let g = Family::ResNet.generate(2);
    let pred = coord.predict(g).unwrap();
    assert!(pred.latency_ms.is_finite() && pred.latency_ms >= 0.0);
    assert!(pred.memory_mb.is_finite());
    assert!(pred.energy_j.is_finite());
    let m = coord.metrics();
    assert_eq!(m.requests, 1);
    assert_eq!(m.errors, 0);
}

#[test]
fn concurrent_requests_are_batched_not_dropped() {
    let coord = Arc::new(coordinator());
    let n = 48;
    let mut rxs = Vec::new();
    for i in 0..n {
        let g = Family::MobileNet.generate(i % 7);
        rxs.push(coord.submit(g));
    }
    let mut ok = 0;
    for rx in rxs {
        let pred = rx.recv().unwrap().unwrap();
        assert!(pred.latency_ms.is_finite());
        ok += 1;
    }
    assert_eq!(ok, n);
    let m = coord.metrics();
    assert_eq!(m.requests, n as u64);
    assert!(
        m.batches < n as u64,
        "expected batching, got {} batches for {n} requests",
        m.batches
    );
    assert!(m.mean_batch_fill() > 1.0);
}

#[test]
fn identical_graphs_get_identical_predictions() {
    let coord = coordinator();
    let g = Family::Vit.generate(3);
    let a = coord.predict(g.clone()).unwrap();
    let b = coord.predict(g).unwrap();
    assert_eq!(a, b);
}

#[test]
fn oversized_graph_is_rejected_gracefully() {
    let coord = coordinator();
    // Fabricate a graph larger than MAX_NODES.
    let mut b = dippm::ir::GraphBuilder::new("t", "too-big", 1);
    let x = b.input(vec![1, 8, 16, 16]);
    let mut h = x;
    for _ in 0..220 {
        h = b.conv_relu(h, 8, 3, 1, 1);
    }
    let g = b.finish();
    let err = coord.predict(g).unwrap_err();
    assert!(format!("{err:#}").contains("max_nodes"), "{err:#}");
    // The coordinator must survive the error.
    let ok = coord.predict(Family::Vgg.generate(0)).unwrap();
    assert!(ok.latency_ms.is_finite());
}

#[test]
fn tcp_end_to_end_all_frameworks() {
    let coord = Arc::new(coordinator());
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    {
        let coord = coord.clone();
        std::thread::spawn(move || {
            tcp::serve(coord, "127.0.0.1:0", move |p| {
                let _ = port_tx.send(p);
            })
            .unwrap();
        });
    }
    let port = port_rx.recv().unwrap();
    let addr = format!("127.0.0.1:{port}");
    let mut client = tcp::Client::connect(&addr).unwrap();

    // One request per framework format, all through the same socket.
    let g = Family::DenseNet.generate(1);
    for fw in [
        Framework::Native,
        Framework::PyTorch,
        Framework::TensorFlow,
        Framework::Paddle,
    ] {
        let model = frontends::export(fw, &g);
        let compact = Json::parse(&model).unwrap().to_string();
        let line = format!("{{\"framework\":\"{}\",\"model\":{compact}}}", fw.name());
        let resp = client.roundtrip(&line).unwrap();
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.path(&["ok"]).as_bool(), Some(true), "{fw:?}: {resp}");
        assert!(v.path(&["latency_ms"]).as_f64().unwrap() >= 0.0);
    }
    // ONNX goes as a string payload.
    let onnx = frontends::export(Framework::Onnx, &g);
    let line = Json::parse(&format!(
        "{{\"framework\":\"onnx\",\"model\":{}}}",
        Json::Str(onnx).to_string()
    ))
    .unwrap()
    .to_string();
    let resp = client.roundtrip(&line).unwrap();
    assert_eq!(
        Json::parse(&resp).unwrap().path(&["ok"]).as_bool(),
        Some(true),
        "{resp}"
    );

    // Malformed request -> structured error, connection stays up.
    let resp = client.roundtrip("{\"model\": 42}").unwrap();
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.path(&["ok"]).as_bool(), Some(false));
    assert!(v.path(&["error"]).as_str().is_some());
    let resp = client.predict_graph(&g).unwrap();
    assert!(resp.contains("\"ok\":true"));
}

#[test]
fn mig_profile_present_in_prediction() {
    let coord = coordinator();
    let pred = coord.predict(Family::EfficientNet.generate(0)).unwrap();
    // Untrained params may predict odd memory; the field must still be
    // well-formed (a known profile name or None).
    if let Some(p) = &pred.mig_profile {
        assert!(dippm::simulator::MigProfile::from_name(p).is_some());
    }
}
