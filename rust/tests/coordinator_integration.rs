//! Integration: the serving coordinator — dynamic batching across threads,
//! the graph-fingerprint prediction cache (hit/miss/eviction counters,
//! single-flight dedup), TCP JSON-lines protocol, error handling.
//!
//! These tests run hermetically on the simulator backend; the full
//! coordinator stack (queue, batcher, cache, single-flight, TCP) is
//! identical under PJRT — one gated test exercises that path when AOT
//! artifacts are built and the real xla bindings are linked.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use dippm::cache::{CacheConfig, Target};
use dippm::coordinator::{
    tcp, Backend, Coordinator, CoordinatorOptions, PredictRequest, RawOutcome,
};
use dippm::frontends::{self, Framework};
use dippm::modelgen::Family;
use dippm::runtime::Runtime;
use dippm::util::json::Json;

fn sim_coordinator(opts: CoordinatorOptions) -> Coordinator {
    Coordinator::start_sim(opts).expect("simulator coordinator always starts")
}

fn cache_off() -> CoordinatorOptions {
    CoordinatorOptions {
        cache: CacheConfig::disabled(),
        ..Default::default()
    }
}

#[test]
fn single_predict_roundtrip() {
    let coord = sim_coordinator(CoordinatorOptions::default());
    let g = Family::ResNet.generate(2);
    let pred = coord.predict(g).unwrap();
    assert!(pred.latency_ms.is_finite() && pred.latency_ms >= 0.0);
    assert!(pred.memory_mb.is_finite());
    assert!(pred.energy_j.is_finite());
    let m = coord.metrics();
    assert_eq!(m.requests, 1);
    assert_eq!(m.errors, 0);
    assert_eq!(m.cache_hits, 0);
    assert_eq!(m.cache_misses, 1);
}

#[test]
fn concurrent_requests_are_batched_not_dropped() {
    // Cache off: this test is about the dynamic batcher, so every request
    // must reach the executor. A generous window (the linger is an eighth
    // of it) keeps the burst batching by size-close regardless of how
    // slowly this thread submits.
    let coord = Arc::new(sim_coordinator(CoordinatorOptions {
        max_wait: Duration::from_millis(50),
        ..cache_off()
    }));
    let n = 48;
    let mut rxs = Vec::new();
    for i in 0..n {
        let g = Family::MobileNet.generate(i % 7);
        rxs.push(coord.submit(g));
    }
    let mut ok = 0;
    for rx in rxs {
        let pred = rx.recv().unwrap().unwrap();
        assert!(pred.latency_ms.is_finite());
        ok += 1;
    }
    assert_eq!(ok, n);
    let m = coord.metrics();
    assert_eq!(m.requests, n as u64);
    assert!(
        m.batches < n as u64,
        "expected batching, got {} batches for {n} requests",
        m.batches
    );
    assert!(m.mean_batch_fill() > 1.0);
    assert!(!m.cache_enabled);
    assert_eq!(m.cache_hits + m.cache_misses, 0);
}

#[test]
fn repeated_graph_is_served_from_cache_without_invoking_the_backend() {
    let coord = sim_coordinator(CoordinatorOptions::default());
    let g = Family::Vit.generate(3);

    let first = coord.predict(g.clone()).unwrap();
    let m1 = coord.metrics();
    assert_eq!(m1.cache_misses, 1);
    assert_eq!(m1.cache_hits, 0);
    assert_eq!(m1.batches, 1);

    // Same architecture again: answered from the LRU — the backend (and
    // the batcher) must not run a second time.
    let second = coord.predict(g.clone()).unwrap();
    assert_eq!(first, second);
    let m2 = coord.metrics();
    assert_eq!(m2.cache_hits, 1);
    assert_eq!(m2.cache_misses, 1);
    assert_eq!(m2.batches, 1, "cache hit must bypass the backend");
    assert_eq!(m2.requests, 2);
    assert_eq!(m2.cache_entries, 1);

    // Node renaming does not defeat the canonical fingerprint.
    let mut renamed = g.clone();
    for node in &mut renamed.nodes {
        node.name = format!("other/{}", node.id);
    }
    renamed.variant = "renamed-variant".into();
    let third = coord.predict(renamed).unwrap();
    assert_eq!(first, third);
    let m3 = coord.metrics();
    assert_eq!(m3.cache_hits, 2);
    assert_eq!(m3.batches, 1);
}

#[test]
fn cache_disabled_knob_forces_backend_execution() {
    let coord = sim_coordinator(cache_off());
    let g = Family::Vgg.generate(1);
    let a = coord.predict(g.clone()).unwrap();
    let b = coord.predict(g).unwrap();
    // The simulator is deterministic, so answers agree even uncached.
    assert_eq!(a, b);
    let m = coord.metrics();
    assert_eq!(m.batches, 2, "cache off: every request hits the backend");
    assert_eq!(m.cache_hits, 0);
    assert_eq!(m.cache_misses, 0);
    assert!(!m.cache_enabled);
}

#[test]
fn cache_ttl_expires_entries() {
    let coord = sim_coordinator(CoordinatorOptions {
        cache: CacheConfig {
            ttl: Some(Duration::ZERO),
            ..Default::default()
        },
        ..Default::default()
    });
    let g = Family::DenseNet.generate(2);
    coord.predict(g.clone()).unwrap();
    coord.predict(g).unwrap();
    let m = coord.metrics();
    // Zero TTL: the second lookup found only an expired entry.
    assert_eq!(m.cache_hits, 0);
    assert_eq!(m.cache_expirations, 1);
    assert_eq!(m.batches, 2);
}

#[test]
fn thundering_herd_of_identical_graphs_coalesces() {
    // A long batching window keeps the leader's batch open while the herd
    // arrives, making the coalescing deterministic.
    let coord = Arc::new(sim_coordinator(CoordinatorOptions {
        max_wait: Duration::from_millis(200),
        ..Default::default()
    }));
    let n = 64u64;
    let g = Family::Swin.generate(1);
    let rxs: Vec<_> = (0..n).map(|_| coord.submit(g.clone())).collect();
    let mut preds = Vec::new();
    for rx in rxs {
        preds.push(rx.recv().unwrap().unwrap());
    }
    assert!(preds.windows(2).all(|w| w[0] == w[1]));
    let m = coord.metrics();
    assert_eq!(m.requests, n);
    // One leader flew; everyone else was a follower or (late arrivals) a
    // cache hit. Either way the backend ran far fewer than n times.
    assert!(
        m.batches <= 2,
        "herd of {n} identical graphs cost {} batches",
        m.batches
    );
    assert!(
        m.coalesced + m.cache_hits >= n - 2,
        "coalesced {} + hits {} should cover the herd",
        m.coalesced,
        m.cache_hits
    );
}

#[test]
fn dedup_disabled_knob_still_caches() {
    let coord = sim_coordinator(CoordinatorOptions {
        cache: CacheConfig {
            single_flight: false,
            ..Default::default()
        },
        ..Default::default()
    });
    let g = Family::PoolFormer.generate(0);
    coord.predict(g.clone()).unwrap();
    coord.predict(g).unwrap();
    let m = coord.metrics();
    assert_eq!(m.coalesced, 0);
    assert_eq!(m.cache_hits, 1);
    assert_eq!(m.batches, 1);
}

#[test]
fn identical_graphs_get_identical_predictions() {
    let coord = sim_coordinator(CoordinatorOptions::default());
    let g = Family::Vit.generate(3);
    let a = coord.predict(g.clone()).unwrap();
    let b = coord.predict(g).unwrap();
    assert_eq!(a, b);
}

fn oversized_graph() -> dippm::ir::Graph {
    // Fabricate a graph larger than MAX_NODES.
    let mut b = dippm::ir::GraphBuilder::new("t", "too-big", 1);
    let x = b.input(vec![1, 8, 16, 16]);
    let mut h = x;
    for _ in 0..220 {
        h = b.conv_relu(h, 8, 3, 1, 1);
    }
    b.finish()
}

#[test]
fn oversized_graph_is_rejected_gracefully() {
    let coord = sim_coordinator(CoordinatorOptions::default());
    let err = coord.predict(oversized_graph()).unwrap_err();
    assert!(format!("{err:#}").contains("max_nodes"), "{err:#}");
    // The coordinator must survive the error; the failure is cached only
    // as a tombstone (negative entry), never as a prediction.
    let ok = coord.predict(Family::Vgg.generate(0)).unwrap();
    assert!(ok.latency_ms.is_finite());
    let m = coord.metrics();
    assert_eq!(m.errors, 1);
    assert_eq!(m.cache_entries, 2, "one prediction + one tombstone");
}

#[test]
fn repeated_poison_graph_is_tombstoned_not_reexecuted() {
    let coord = sim_coordinator(CoordinatorOptions::default());
    let g = oversized_graph();
    let e1 = coord.predict(g.clone()).unwrap_err();
    let batches_after_first = coord.metrics().batches;
    // Second submission: answered from the tombstone on the submit path —
    // the executor (and the backend) never see the graph again.
    let e2 = coord.predict(g.clone()).unwrap_err();
    let m = coord.metrics();
    assert_eq!(m.batches, batches_after_first, "tombstone hit must not batch");
    assert_eq!(m.negative_hits, 1);
    assert_eq!(m.errors, 1, "tombstone replay is not a new executor error");
    assert!(format!("{e1:#}").contains("max_nodes"));
    assert!(format!("{e2:#}").contains("max_nodes"), "{e2:#}");
}

#[test]
fn negative_caching_can_be_disabled() {
    let coord = sim_coordinator(CoordinatorOptions {
        cache: CacheConfig {
            negative_ttl: None,
            ..Default::default()
        },
        ..Default::default()
    });
    let g = oversized_graph();
    coord.predict(g.clone()).unwrap_err();
    coord.predict(g).unwrap_err();
    let m = coord.metrics();
    assert_eq!(m.negative_hits, 0);
    assert_eq!(m.errors, 2, "without tombstones both submissions execute");
    assert_eq!(m.cache_entries, 0);
}

#[test]
fn same_graph_two_targets_is_two_backend_executions() {
    let coord = sim_coordinator(CoordinatorOptions::default());
    let g = Family::ResNet.generate(1);
    let full = coord
        .predict_to(g.clone(), Some(Target::default()))
        .unwrap();
    let m1 = coord.metrics();
    assert_eq!((m1.cache_hits, m1.cache_misses), (0, 1));

    // Same graph, sliced target: a distinct composite key — a miss, a new
    // backend execution, and a different (slower) answer.
    let slice = coord
        .predict_to(g.clone(), Some(Target::parse("a100:1g.5gb").unwrap()))
        .unwrap();
    let m2 = coord.metrics();
    assert_eq!((m2.cache_hits, m2.cache_misses), (0, 2));
    assert_eq!(m2.batches, 2);
    assert_eq!(m2.cache_entries, 2);
    assert!(
        slice.latency_ms > full.latency_ms,
        "1/7th slice must be slower: {} vs {}",
        slice.latency_ms,
        full.latency_ms
    );

    // Each target now hits its own entry.
    coord.predict_to(g.clone(), Some(Target::default())).unwrap();
    coord
        .predict_to(g, Some(Target::parse("a100:1g.5gb").unwrap()))
        .unwrap();
    let m3 = coord.metrics();
    assert_eq!(m3.cache_hits, 2);
    assert_eq!(m3.batches, 2, "both repeats were cache hits");
}

#[test]
fn tcp_end_to_end_all_frameworks() {
    let coord = Arc::new(sim_coordinator(CoordinatorOptions::default()));
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    {
        let coord = coord.clone();
        std::thread::spawn(move || {
            tcp::serve(coord, "127.0.0.1:0", move |p| {
                let _ = port_tx.send(p);
            })
            .unwrap();
        });
    }
    let port = port_rx.recv().unwrap();
    let addr = format!("127.0.0.1:{port}");
    let mut client = tcp::Client::connect(&addr).unwrap();

    // One request per framework format, all through the same socket. All
    // five lower to the same graph, so after the first miss the cache
    // serves every format — the cross-frontend canonicalization at work.
    let g = Family::DenseNet.generate(1);
    for fw in [
        Framework::Native,
        Framework::PyTorch,
        Framework::TensorFlow,
        Framework::Paddle,
    ] {
        let model = frontends::export(fw, &g);
        let compact = Json::parse(&model).unwrap().to_string();
        let line = format!("{{\"framework\":\"{}\",\"model\":{compact}}}", fw.name());
        let resp = client.roundtrip(&line).unwrap();
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.path(&["ok"]).as_bool(), Some(true), "{fw:?}: {resp}");
        assert!(v.path(&["latency_ms"]).as_f64().unwrap() >= 0.0);
    }
    // ONNX goes as a string payload.
    let onnx = frontends::export(Framework::Onnx, &g);
    let line = Json::parse(&format!(
        "{{\"framework\":\"onnx\",\"model\":{}}}",
        Json::Str(onnx).to_string()
    ))
    .unwrap()
    .to_string();
    let resp = client.roundtrip(&line).unwrap();
    assert_eq!(
        Json::parse(&resp).unwrap().path(&["ok"]).as_bool(),
        Some(true),
        "{resp}"
    );

    // cache_stats admin command: 5 submissions of one architecture = 1
    // miss + 4 hits (all five frontends round-trip to the same graph).
    let stats = client.cache_stats().unwrap();
    let v = Json::parse(&stats).unwrap();
    assert_eq!(v.path(&["ok"]).as_bool(), Some(true), "{stats}");
    assert_eq!(v.path(&["cache_enabled"]).as_bool(), Some(true));
    assert_eq!(v.path(&["misses"]).as_usize(), Some(1), "{stats}");
    assert_eq!(v.path(&["hits"]).as_usize(), Some(4), "{stats}");
    assert_eq!(v.path(&["requests"]).as_usize(), Some(5), "{stats}");
    // Analyze-once observability: of 5 submissions only the single miss
    // built (and the backend consumed) a full analysis; the 4 hits
    // stopped at the cost-sweep/fingerprint stage.
    assert_eq!(v.path(&["analyses_computed"]).as_usize(), Some(1), "{stats}");
    assert_eq!(v.path(&["analyses_reused"]).as_usize(), Some(1), "{stats}");
    assert_eq!(v.path(&["executor_threads"]).as_usize(), Some(1), "{stats}");
    // Batch-former observability: the mode, the latency histogram (one
    // backend-served request so far) and the queue/ring gauges.
    assert_eq!(v.path(&["batch_former"]).as_str(), Some("leader"), "{stats}");
    assert_eq!(v.path(&["latency_count"]).as_usize(), Some(1), "{stats}");
    assert!(v.path(&["latency_p99_us"]).as_usize().unwrap() > 0, "{stats}");
    assert!(
        v.path(&["latency_p50_us"]).as_usize().unwrap()
            <= v.path(&["latency_p99_us"]).as_usize().unwrap(),
        "{stats}"
    );
    assert_eq!(v.path(&["queue_depth"]).as_usize(), Some(0), "{stats}");
    assert!(v.path(&["queue_depth_hwm"]).as_usize().unwrap() >= 1, "{stats}");
    assert!(v.path(&["queue_residency_max_us"]).as_usize().is_some(), "{stats}");

    // Malformed request -> structured error, connection stays up.
    let resp = client.roundtrip("{\"model\": 42}").unwrap();
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.path(&["ok"]).as_bool(), Some(false));
    assert!(v.path(&["error"]).as_str().is_some());
    // Unknown admin command -> structured error.
    let resp = client.roundtrip("{\"cmd\":\"frobnicate\"}").unwrap();
    assert!(resp.contains("\"ok\":false"), "{resp}");
    let resp = client.predict_graph(&g).unwrap();
    assert!(resp.contains("\"ok\":true"));
}

#[test]
fn tcp_target_field_selects_cache_entry() {
    let coord = Arc::new(sim_coordinator(CoordinatorOptions::default()));
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    {
        let coord = coord.clone();
        std::thread::spawn(move || {
            tcp::serve(coord, "127.0.0.1:0", move |p| {
                let _ = port_tx.send(p);
            })
            .unwrap();
        });
    }
    let port = port_rx.recv().unwrap();
    let mut client = tcp::Client::connect(&format!("127.0.0.1:{port}")).unwrap();

    let g = Family::MobileNet.generate(2);
    let full = client.predict_graph(&g).unwrap();
    let sliced = client.predict_graph_on(&g, "a100:2g.10gb").unwrap();
    let full_v = Json::parse(&full).unwrap();
    let sliced_v = Json::parse(&sliced).unwrap();
    assert_eq!(full_v.path(&["ok"]).as_bool(), Some(true), "{full}");
    assert_eq!(sliced_v.path(&["ok"]).as_bool(), Some(true), "{sliced}");
    assert!(
        sliced_v.path(&["latency_ms"]).as_f64().unwrap()
            > full_v.path(&["latency_ms"]).as_f64().unwrap()
    );
    // Two targets, two entries; a bad target is a structured error.
    let stats = Json::parse(&client.cache_stats().unwrap()).unwrap();
    assert_eq!(stats.path(&["entries"]).as_usize(), Some(2));
    let bad = client.predict_graph_on(&g, "a100:9g.80gb").unwrap();
    assert!(bad.contains("\"ok\":false"), "{bad}");
}

#[test]
fn analysis_reuse_counters_are_observable() {
    let coord = sim_coordinator(CoordinatorOptions::default());
    let g = Family::ResNet.generate(0);
    coord.predict(g.clone()).unwrap(); // miss: full analysis built + consumed
    coord.predict(g).unwrap(); // hit: stops at the cost-sweep/fingerprint stage
    let m = coord.metrics();
    assert_eq!(m.requests, 2);
    assert_eq!(
        m.analyses_computed, 1,
        "only the enqueued miss builds the full analysis; the hit stops at the key"
    );
    assert_eq!(
        m.analyses_reused, 1,
        "the backend-served request consumed its carried analysis"
    );
    assert_eq!(m.executor_threads, 1);
}

#[test]
fn parallel_executor_serves_concurrent_misses_correctly() {
    // 4 workers, every request a distinct architecture (cache on but all
    // misses): the pool must serve everything exactly once, with answers
    // identical to the single-threaded coordinator's.
    let parallel = Arc::new(sim_coordinator(CoordinatorOptions {
        executor_threads: 4,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    }));
    let serial = sim_coordinator(CoordinatorOptions::default());
    let n = 48;
    let graphs: Vec<_> = (0..n)
        .map(|i| Family::MobileNet.generate(i % 7))
        .collect();
    // 7 distinct architectures, re-submitted: repeats resolve as cache
    // hits or coalesced followers, distinct ones fan out across workers.
    let rxs: Vec<_> = graphs.iter().map(|g| parallel.submit(g.clone())).collect();
    for (g, rx) in graphs.iter().zip(rxs) {
        let got = rx.recv().unwrap().unwrap();
        let want = serial.predict(g.clone()).unwrap();
        assert_eq!(got, want, "parallel pool must not change answers");
    }
    let m = parallel.metrics();
    assert_eq!(m.requests, n as u64);
    assert_eq!(m.errors, 0);
    assert_eq!(m.executor_threads, 4);
    // Only enqueued misses build a full analysis (repeats resolve as hits
    // or coalesced followers at the cost-sweep stage), and every enqueued
    // job's analysis was consumed by a backend.
    assert!(m.analyses_computed >= 7, "one per distinct architecture");
    assert!(m.analyses_computed <= n as u64);
    assert_eq!(m.analyses_reused, m.analyses_computed);
}

/// A backend for admission-order tests: max_batch 1, records the variant
/// of everything it serves, and blocks inside the first call until the
/// test opens the gate — letting the test stack up queued misses with
/// different single-flight follower counts behind a busy executor.
struct GatedBackend {
    served: Arc<Mutex<Vec<String>>>,
    entered: mpsc::Sender<()>,
    gate: Arc<(Mutex<bool>, Condvar)>,
    gated_once: bool,
}

impl Backend for GatedBackend {
    fn name(&self) -> &'static str {
        "gated"
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn predict_into(
        &mut self,
        requests: &[PredictRequest<'_>],
        out: &mut Vec<RawOutcome>,
    ) -> anyhow::Result<()> {
        for req in requests {
            self.served.lock().unwrap().push(req.graph.variant.clone());
        }
        let _ = self.entered.send(());
        if !self.gated_once {
            self.gated_once = true;
            let (open, cv) = &*self.gate;
            let mut open = open.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        }
        out.extend(
            requests
                .iter()
                .map(|req| Ok([1.0, 100.0 + req.graph.n_nodes() as f64, 1.0])),
        );
        Ok(())
    }
}

#[test]
fn cache_aware_admission_prefers_misses_with_more_followers() {
    let served = Arc::new(Mutex::new(Vec::new()));
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let (entered_tx, entered_rx) = mpsc::channel();
    // The factory must be Sync (it is shared across the worker pool);
    // park the sender behind a mutex rather than relying on Sender: Sync.
    let entered_tx = Arc::new(Mutex::new(entered_tx));
    let coord = {
        let served = served.clone();
        let gate = gate.clone();
        Coordinator::start_with_backend(
            Box::new(move || {
                Ok(Box::new(GatedBackend {
                    served: served.clone(),
                    entered: entered_tx.lock().unwrap().clone(),
                    gate: gate.clone(),
                    gated_once: false,
                }) as Box<dyn Backend>)
            }),
            CoordinatorOptions {
                max_wait: Duration::ZERO,
                ..Default::default()
            },
        )
        .unwrap()
    };

    let g_first = Family::Vgg.generate(0);
    let g_cold = Family::ResNet.generate(0); // will have 0 followers
    let g_hot = Family::MobileNet.generate(0); // will gather 3 followers

    // Occupy the executor: the first miss blocks inside the backend.
    let rx_first = coord.submit(g_first);
    entered_rx.recv().unwrap();

    // While the executor is busy, enqueue an older cold miss, then a hot
    // miss whose 3 re-submissions park as single-flight followers.
    let rx_cold = coord.submit(g_cold);
    let rx_hot = coord.submit(g_hot.clone());
    let follower_rxs: Vec<_> = (0..3).map(|_| coord.submit(g_hot.clone())).collect();

    // Open the gate: the executor finishes the first batch, then admits
    // from a queue holding [cold(0 followers), hot(3 followers)].
    {
        let (open, cv) = &*gate;
        *open.lock().unwrap() = true;
        cv.notify_all();
    }

    rx_first.recv().unwrap().unwrap();
    let hot_pred = rx_hot.recv().unwrap().unwrap();
    for rx in follower_rxs {
        assert_eq!(rx.recv().unwrap().unwrap(), hot_pred);
    }
    rx_cold.recv().unwrap().unwrap();

    let order = served.lock().unwrap().clone();
    assert_eq!(order.len(), 3, "3 distinct misses reached the backend");
    assert_eq!(order[0], g_first.variant);
    assert_eq!(
        order[1],
        Family::MobileNet.generate(0).variant,
        "the miss with 3 parked followers must be admitted before the older 0-follower miss: {order:?}"
    );
    assert_eq!(order[2], Family::ResNet.generate(0).variant);

    let m = coord.metrics();
    assert_eq!(m.batches, 3, "max_batch=1: one batch per distinct miss");
    assert_eq!(m.batch_fill_sum, 3, "batch fill reflects the 3 admissions");
    assert_eq!(m.coalesced, 3, "the 3 followers were woken by the leader");
    assert!(m.priority_admissions >= 1, "the jump must be counted");
    assert_eq!(m.requests, 6);
}

#[test]
fn mig_profile_present_in_prediction() {
    let coord = sim_coordinator(CoordinatorOptions::default());
    let pred = coord.predict(Family::EfficientNet.generate(0)).unwrap();
    // The field must be well-formed (a known profile name or None).
    if let Some(p) = &pred.mig_profile {
        assert!(dippm::simulator::MigProfile::from_name(p).is_some());
    }
}

#[test]
fn pjrt_backend_roundtrip_when_artifacts_built() {
    // Exercised only with `make artifacts` + the real xla bindings; the
    // offline stub (or a missing artifacts/ dir) skips.
    let Ok(rt) = Runtime::new("artifacts") else {
        eprintln!("skipping: PJRT runtime/artifacts unavailable");
        return;
    };
    let params = rt.init_params("sage", 0).unwrap();
    drop(rt); // the coordinator builds its own runtime in its executor
    let coord =
        Coordinator::start("artifacts", params, CoordinatorOptions::default()).unwrap();
    let g = Family::ResNet.generate(2);
    let a = coord.predict(g.clone()).unwrap();
    assert!(a.latency_ms.is_finite());
    // The cache fronts the PJRT backend identically.
    let b = coord.predict(g).unwrap();
    assert_eq!(a, b);
    let m = coord.metrics();
    assert_eq!(m.batches, 1);
    assert_eq!(m.cache_hits, 1);
}
