//! Chaos: deterministic fault-injection runs over the serving stack.
//!
//! Every scenario arms a seeded [`FaultPlan`] (`util::faults`), drives
//! real traffic through a coordinator (and, for wire faults, a reactor),
//! and asserts the robustness invariants the supervision layer promises:
//!
//! * **exactly-one-reply** — under backend errors, panics and latency
//!   spikes, every submitted request gets exactly one reply (`Ok` or
//!   `Err`), and the request counter never drifts from the reply count;
//! * **breaker lifecycle** — injected panics trip the circuit breaker,
//!   misses are then served degraded (tagged, never cached) by the
//!   simulator fallback, and once faults stop the half-open probe closes
//!   the breaker again — all observable through `Metrics`/`cache_stats`;
//! * **deadline shedding** — an expired budget shed at admission or
//!   pre-execution never reaches the backend;
//! * **quarantine** — a key that crashes the backend twice is poisoned
//!   (short-TTL tombstone) instead of crashing a third backend;
//! * **determinism** — identical plan seeds reproduce identical
//!   per-point injection sequences end to end;
//! * **wire survival** — torn/dropped frames cost at most the affected
//!   connection; the reactor keeps serving new ones.
//!
//! The fault plan is process-global, so every test serializes on one
//! mutex and disarms the plan on scenario exit (drop guard). The base
//! seed comes from `DIPPM_CHAOS_SEED` (CI matrixes it); each scenario
//! derives its own stream so seeds never collide across tests.

use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use dippm::coordinator::{
    protocol, Backend, BatchFormerMode, Coordinator, CoordinatorOptions, PredictRequest,
    RawOutcome,
};
use dippm::modelgen::{Family, ALL_FAMILIES};
use dippm::util::faults::{self, FaultPlan};
use dippm::wire::{reactor, ReactorConfig, WireClient};

/// One plan at a time: the fault registry is process-global and cargo
/// runs test threads in parallel.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

/// Plan guard: holds the chaos lock and disarms the plan on drop, so a
/// failing scenario cannot leak faults into the next one.
struct ArmedPlan {
    _guard: MutexGuard<'static, ()>,
}

impl Drop for ArmedPlan {
    fn drop(&mut self) {
        faults::install(None);
    }
}

/// Serialize + arm `spec` (`""` = hold the lock with no plan armed).
fn arm(spec: &str) -> ArmedPlan {
    let guard = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if spec.is_empty() {
        faults::install(None);
    } else {
        faults::install(Some(FaultPlan::parse(spec).expect("valid plan spec")));
    }
    ArmedPlan { _guard: guard }
}

/// CI matrixes this; locally every run uses the same default stream.
fn base_seed() -> u64 {
    std::env::var("DIPPM_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(101)
}

fn opts(threads: usize, mode: BatchFormerMode) -> CoordinatorOptions {
    CoordinatorOptions {
        executor_threads: threads,
        batch_former: mode,
        max_wait: Duration::from_millis(2),
        ..Default::default()
    }
}

/// Distinct architectures per index — every request is a real cache miss.
fn graph(i: usize) -> dippm::ir::Graph {
    ALL_FAMILIES[i % ALL_FAMILIES.len()].generate(i)
}

/// Workers reply before folding counters into `Metrics`, so poll until
/// `cond` holds (or time out and return the last snapshot).
fn metrics_when(
    coord: &Coordinator,
    cond: impl Fn(&dippm::coordinator::Metrics) -> bool,
) -> dippm::coordinator::Metrics {
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let m = coord.metrics();
        if cond(&m) || std::time::Instant::now() >= deadline {
            return m;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ------------------------------------------------- exactly one reply ---

#[test]
fn every_request_replies_exactly_once_under_backend_chaos() {
    let base = base_seed();
    // Four independent fault-plan seeds (the acceptance floor): same
    // invariant must hold under every injection sequence.
    for round in 0..4u64 {
        let seed = base.wrapping_mul(1000) + round;
        let _plan = arm(&format!(
            "{seed}:backend:panic=0.25,backend:error=0.25,backend:latency=0.3"
        ));
        let coord = Coordinator::start_sim(CoordinatorOptions {
            // High threshold: keep the breaker closed so every request
            // exercises the supervised backend path, not the fallback.
            breaker_threshold: 1000,
            ..opts(2, BatchFormerMode::Leader)
        })
        .unwrap();
        const N: usize = 24;
        let receivers: Vec<_> = (0..N).map(|i| coord.submit(graph(i))).collect();
        let (mut oks, mut errs) = (0u64, 0u64);
        for (i, rx) in receivers.into_iter().enumerate() {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(_)) => oks += 1,
                Ok(Err(_)) => errs += 1,
                Err(e) => panic!("request {i} never replied (seed {seed}): {e}"),
            }
            // Exactly one: the reply channel must now be spent.
            assert!(
                rx.try_recv().is_err(),
                "request {i} got a second reply (seed {seed})"
            );
        }
        assert_eq!(oks + errs, N as u64);
        let m = metrics_when(&coord, |m| {
            m.requests == N as u64 && m.backend_restarts == m.backend_panics
        });
        assert_eq!(m.requests, N as u64, "request counter drifted (seed {seed})");
        assert!(m.batches >= 1);
        // No deadline was set, so nothing may have been shed as expired.
        assert_eq!(m.deadline_expired, 0, "phantom deadline sheds (seed {seed})");
        // Panic accounting is consistent: each counted panic restarted a
        // backend (or shutdown began, which it didn't — we're still up).
        let plan = faults::active_plan().expect("plan armed");
        let fired_panics = plan
            .counters()
            .iter()
            .find(|c| c.0 == "backend:panic")
            .map(|c| c.2)
            .unwrap_or(0);
        assert_eq!(m.backend_panics, fired_panics, "panic counter drift");
        assert_eq!(m.backend_restarts, m.backend_panics);
    }
}

#[test]
fn serving_recovers_fully_after_faults_stop() {
    let seed = base_seed().wrapping_mul(1000) + 17;
    let _plan = arm(&format!("{seed}:backend:panic=0.5,backend:error=0.5"));
    let coord = Coordinator::start_sim(CoordinatorOptions {
        breaker_threshold: 1000,
        ..opts(2, BatchFormerMode::Leader)
    })
    .unwrap();
    for i in 0..8 {
        let _ = coord.predict(graph(i)); // errors expected and allowed
    }
    let errors_during = coord.metrics().errors;
    // Faults off: every subsequent request must succeed — the workers
    // rebuilt their backends and no poisoned state lingers.
    faults::install(None);
    for i in 8..16 {
        coord
            .predict(graph(i))
            .unwrap_or_else(|e| panic!("request {i} failed after faults cleared: {e:#}"));
    }
    let m = metrics_when(&coord, |m| m.requests == 16);
    assert_eq!(m.errors, errors_during, "errors kept growing after recovery");
}

// ------------------------------------------------- breaker lifecycle ---

#[test]
fn breaker_opens_serves_degraded_then_recovers() {
    let seed = base_seed().wrapping_mul(1000) + 29;
    let _plan = arm(&format!("{seed}:backend:panic=1"));
    let cooldown = Duration::from_millis(500);
    let coord = Coordinator::start_sim(CoordinatorOptions {
        breaker_threshold: 2,
        breaker_cooldown: cooldown,
        ..opts(1, BatchFormerMode::Off)
    })
    .unwrap();

    // Two consecutive panicking batches trip the breaker.
    assert!(coord.predict(graph(100)).is_err());
    assert!(coord.predict(graph(101)).is_err());
    let m = coord.metrics();
    assert_eq!(m.breaker_state, "open", "breaker did not open");
    assert_eq!(m.breaker_trips, 1);
    assert_eq!(m.backend_panics, 2);

    // Open breaker: misses are served by the simulator fallback, tagged.
    let p = coord.predict(graph(102)).expect("degraded miss must serve");
    assert!(p.degraded, "fallback prediction must carry the degraded tag");
    let m = coord.metrics();
    assert!(m.degraded_served >= 1);
    // The operator-facing document carries the whole story.
    let stats = protocol::cache_stats_response(&m);
    assert!(stats.contains("\"breaker_state\":\"open\""), "{stats}");
    assert!(stats.contains("\"degraded_served\":"), "{stats}");
    assert!(stats.contains("\"backend_panics\":2"), "{stats}");

    // Degraded predictions are never cached: re-asking the same graph
    // after recovery must reach the real backend (asserted below by the
    // un-tagged answer).
    faults::install(None);
    std::thread::sleep(cooldown + Duration::from_millis(150));
    // First request after the cooldown is the half-open probe; the
    // healthy backend answers and the breaker closes.
    let p = coord.predict(graph(102)).expect("probe must serve");
    assert!(!p.degraded, "authoritative answer must not be tagged degraded");
    let m = metrics_when(&coord, |m| m.breaker_state == "closed");
    assert_eq!(m.breaker_state, "closed", "breaker did not close after probe");
    assert_eq!(m.backend_restarts, 2, "each caught panic rebuilds a backend");
}

// ------------------------------------------------------- quarantine ---

#[test]
fn key_that_crashes_two_backends_is_quarantined() {
    let seed = base_seed().wrapping_mul(1000) + 43;
    let _plan = arm(&format!("{seed}:backend:panic=1"));
    let coord = Coordinator::start_sim(CoordinatorOptions {
        breaker_threshold: 1000,
        ..opts(1, BatchFormerMode::Off)
    })
    .unwrap();
    let g = Family::Vgg.generate(3);
    // Crash one: counted, not yet quarantined.
    assert!(coord.predict(g.clone()).is_err());
    // Crash two: quarantined — a poison tombstone through the negative
    // cache.
    let e = coord.predict(g.clone()).unwrap_err();
    assert!(e.to_string().contains("quarantined"), "{e:#}");
    // Third ask is answered from the tombstone on the submit path: no
    // third backend dies.
    let e = coord.predict(g).unwrap_err();
    assert!(e.to_string().contains("quarantined"), "{e:#}");
    let m = metrics_when(&coord, |m| m.quarantined == 1);
    assert_eq!(m.quarantined, 1);
    assert_eq!(m.backend_panics, 2, "the tombstone must absorb the third ask");
    assert!(m.negative_hits >= 1);
}

// -------------------------------------------------- deadline shedding ---

/// A backend whose very first `predict_into` blocks until the gate
/// opens — wedges the single worker so queued jobs outlive their budget.
struct FirstCallGate {
    /// (armed, open)
    state: Arc<(Mutex<(bool, bool)>, Condvar)>,
}

impl Backend for FirstCallGate {
    fn name(&self) -> &'static str {
        "first-call-gate"
    }

    fn max_batch(&self) -> usize {
        4
    }

    fn predict_into(
        &mut self,
        requests: &[PredictRequest<'_>],
        out: &mut Vec<RawOutcome>,
    ) -> anyhow::Result<()> {
        let (lock, cv) = &*self.state;
        let mut s = lock.lock().unwrap();
        if s.0 {
            s.0 = false;
            while !s.1 {
                s = cv.wait(s).unwrap();
            }
        }
        drop(s);
        out.extend(
            requests
                .iter()
                .map(|req| Ok([1.0, 100.0 + req.graph.n_nodes() as f64, 1.0])),
        );
        Ok(())
    }
}

#[test]
fn expired_deadlines_shed_before_the_backend_runs() {
    // No fault plan: deadline shedding is supervision, not chaos — but
    // hold the lock so another scenario's plan can't bleed in.
    let _plan = arm("");
    let state = Arc::new((Mutex::new((true, false)), Condvar::new()));
    let coord = {
        let state = state.clone();
        Coordinator::start_with_backend(
            Box::new(move || {
                Ok(Box::new(FirstCallGate {
                    state: state.clone(),
                }) as Box<dyn Backend>)
            }),
            opts(1, BatchFormerMode::Off),
        )
        .unwrap()
    };

    // Admission shed: an already-spent budget never enqueues.
    let e = coord
        .predict_deadline(graph(0), None, Some(Duration::ZERO))
        .unwrap_err();
    assert!(e.to_string().contains("deadline expired"), "{e:#}");

    // Wedge the only worker with an un-budgeted request…
    let rx_wedged = coord.submit(graph(1));
    loop {
        if !state.0.lock().unwrap().0 {
            break; // the gate is held
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    // …queue a tightly-budgeted one behind it and let the budget expire.
    let rx_late = coord.submit_deadline(
        graph(2),
        dippm::cache::Target::default(),
        Some(Duration::from_millis(10)),
    );
    std::thread::sleep(Duration::from_millis(60));
    // Open the gate: the wedged request serves; the expired one is shed
    // before its batch reaches the backend.
    {
        let (lock, cv) = &*state;
        lock.lock().unwrap().1 = true;
        cv.notify_all();
    }
    rx_wedged
        .recv_timeout(Duration::from_secs(10))
        .expect("wedged request must reply")
        .expect("wedged request must serve");
    let late = rx_late
        .recv_timeout(Duration::from_secs(10))
        .expect("shed request must still reply");
    let e = late.expect_err("expired request must not serve");
    assert!(e.to_string().contains("deadline expired"), "{e:#}");

    let m = metrics_when(&coord, |m| m.deadline_expired >= 2);
    assert_eq!(m.shed_admission, 1);
    assert_eq!(
        m.shed_formation + m.shed_execution,
        1,
        "the queued expiry sheds exactly once in the pipeline"
    );
    assert_eq!(m.deadline_expired, 2);
    // The shed batch never invoked the backend: only the wedged request's
    // batch executed.
    assert_eq!(m.batches, 1, "an expired job reached the backend");
}

// ----------------------------------------------------- determinism ---

#[test]
fn identical_seeds_reproduce_identical_injection_sequences() {
    let _guard = arm("");
    let seed = base_seed().wrapping_mul(1000) + 77;
    let spec = format!(
        "{seed}:backend:panic=0.4,backend:error=0.3,backend:latency=0.5"
    );
    // Sequential single-worker runs: the per-point decision order is a
    // pure function of the plan seed, so two full serving runs must
    // consult and fire every point identically.
    let run = || {
        faults::install(Some(FaultPlan::parse(&spec).unwrap()));
        let coord = Coordinator::start_sim(CoordinatorOptions {
            breaker_threshold: 1000,
            ..opts(1, BatchFormerMode::Off)
        })
        .unwrap();
        for i in 0..16 {
            let _ = coord.predict(graph(i));
        }
        let counters = faults::active_plan().expect("armed").counters();
        faults::install(None);
        counters
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed, same workload, different injections");
    assert!(
        a.iter().any(|&(_, checked, _)| checked > 0),
        "the plan was never consulted: {a:?}"
    );
    assert!(
        a.iter().any(|&(_, _, fired)| fired > 0),
        "nothing ever fired at these probabilities: {a:?}"
    );
}

// ---------------------------------------------------- wire survival ---

fn start_reactor(coord: Arc<Coordinator>) -> String {
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        reactor::serve(coord, "127.0.0.1:0", ReactorConfig::default(), move |p| {
            let _ = port_tx.send(p);
        })
        .unwrap();
    });
    format!("127.0.0.1:{}", port_rx.recv().unwrap())
}

#[test]
fn reactor_survives_torn_reply_frames() {
    let seed = base_seed().wrapping_mul(1000) + 88;
    let _plan = arm(&format!("{seed}:wire:torn-frame=1"));
    let coord = Arc::new(Coordinator::start_sim(CoordinatorOptions::default()).unwrap());
    let addr = start_reactor(coord.clone());

    // Every reply is torn mid-frame and the connection closed: the
    // client sees a transport error, never a corrupt prediction.
    let mut client = WireClient::connect(&addr).unwrap();
    client.send_predict(&graph(0), None).unwrap();
    assert!(
        client.recv_reply().is_err(),
        "a torn frame must not decode into a reply"
    );

    // The blast radius is that one connection: faults off, the server
    // keeps accepting and serving.
    faults::install(None);
    let mut client = WireClient::connect(&addr).unwrap();
    let pred = client.predict_graph(&graph(1)).unwrap();
    assert!(!pred.degraded);
}

#[test]
fn reactor_survives_dropped_request_frames() {
    let seed = base_seed().wrapping_mul(1000) + 99;
    let _plan = arm(&format!("{seed}:wire:drop-frame=1"));
    let coord = Arc::new(Coordinator::start_sim(CoordinatorOptions::default()).unwrap());
    let addr = start_reactor(coord.clone());
    let armed = faults::active_plan().expect("plan armed");

    let mut client = WireClient::connect(&addr).unwrap();
    let dropped_seq = client.send_predict(&graph(0), None).unwrap();
    // Give the reactor time to decode (and drop) the frame, then disarm.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let fired = armed
            .counters()
            .iter()
            .find(|c| c.0 == "wire:drop-frame")
            .map(|c| c.2)
            .unwrap_or(0);
        if fired >= 1 || std::time::Instant::now() >= deadline {
            assert!(fired >= 1, "the request frame was never dropped");
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    faults::install(None);

    // The connection itself survived the drop: the next request on the
    // same socket serves, and the reply matches *its* sequence id.
    let live_seq = client.send_predict(&graph(1), None).unwrap();
    let (seq, reply) = client.recv_reply().unwrap();
    assert_eq!(seq, live_seq, "reply for the dropped frame materialized");
    assert_ne!(seq, dropped_seq);
    reply.expect("post-drop request must serve");

    // Stats still flow on a fresh connection (server-wide health).
    let mut probe = WireClient::connect(&addr).unwrap();
    let stats = probe.stats().unwrap();
    assert!(stats.contains("\"breaker_state\""), "{stats}");
}
