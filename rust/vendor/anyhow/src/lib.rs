//! Offline stand-in for the `anyhow` crate, implementing the subset dippm
//! uses: [`Error`], [`Result`], the [`anyhow!`] macro and the [`Context`]
//! extension trait. Error values carry a message plus an optional cause
//! chain; `{}` prints the outermost message and `{:#}` prints the whole
//! chain separated by `: `, matching real-anyhow formatting closely enough
//! for tests that grep error text.

use std::fmt;

/// A message-chain error type. Unlike real anyhow this does not box the
/// original error value — only its rendered message chain — which is all
/// the dippm code base relies on.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            cause: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    /// The outermost message (without the cause chain).
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// Iterate over the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.cause.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        msgs.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            f.write_str("\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

/// Every std error converts into `Error`, preserving its source chain as
/// rendered messages. This powers `?` on io/xla results inside functions
/// returning `anyhow::Result`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(match err {
                None => Error { msg, cause: None },
                Some(inner) => Error {
                    msg,
                    cause: Some(Box::new(inner)),
                },
            });
        }
        err.expect("at least one message")
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e:#}").contains("missing file"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: missing file");
        let o: Option<u32> = None;
        let e = o.context("value absent").unwrap_err();
        assert_eq!(format!("{e}"), "value absent");
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("got {n} items");
        assert_eq!(b.to_string(), "got 3 items");
        let c = anyhow!("got {} items", 4);
        assert_eq!(c.to_string(), "got 4 items");
        let msg = String::from("from a string");
        let d = anyhow!(msg);
        assert_eq!(d.to_string(), "from a string");
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "boom 7");
    }
}
