//! API-compatible offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The dippm runtime layer (`rust/src/runtime/`) is written against the real
//! bindings; this stub provides the same types and signatures so the crate
//! builds and tests run on machines without the XLA shared library. Host-side
//! data plumbing ([`Literal`], [`ArrayShape`], [`ElementType`]) is fully
//! functional; device execution entry points ([`PjRtClient::cpu`]) return a
//! descriptive error, which the coordinator surfaces as "use the simulator
//! backend". Swapping this path dependency for the real crate re-enables the
//! PJRT path with no source changes.

use std::fmt;

/// Error type mirroring xla-rs (message-only in the stub).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime is not available in this offline build (stub xla crate); \
         use the simulator backend or link the real xla-rs crate"
            .to_string(),
    )
}

/// Element types of literals (subset dippm uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        4
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn write_le(self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// Dense array shape (dims in elements).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host literal: element type + dims + row-major little-endian bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    /// Rank-0 scalar literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut bytes = Vec::with_capacity(4);
        v.write_le(&mut bytes);
        Literal {
            ty: T::TY,
            dims: Vec::new(),
            bytes,
        }
    }

    /// Build from a shape and raw untyped bytes (the zero-copy entry point
    /// of the real bindings; the stub copies).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        if numel * ty.byte_size() != data.len() {
            return Err(Error(format!(
                "literal data is {} bytes, shape {dims:?} needs {}",
                data.len(),
                numel * ty.byte_size()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    /// Element type; errors on tuple literals in the real bindings (the
    /// stub has no tuples, so this always succeeds).
    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    /// Decompose a tuple literal. The stub never produces tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error("stub literals are never tuples".to_string()))
    }

    /// Read back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let sz = self.ty.byte_size();
        Ok(self.bytes.chunks_exact(sz).map(T::read_le).collect())
    }
}

/// Parsed HLO module (the stub only retains the source path).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    /// Load HLO text. File-existence errors are real; parsing is deferred
    /// to compile time in the actual bindings and skipped by the stub.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::metadata(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto {
            path: path.to_string(),
        })
    }
}

/// An XLA computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            path: proto.path.clone(),
        }
    }
}

/// PJRT client. Device execution is unavailable in the stub: construction
/// fails with a descriptive error so callers can fall back gracefully.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// A compiled executable (unreachable in the stub — clients cannot be
/// constructed — but the type and signatures must exist).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let l = Literal::scalar(1.5f32);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.5]);
        let i = Literal::scalar(-7i32);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![-7]);
    }

    #[test]
    fn untyped_roundtrip() {
        let data: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let l =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes)
                .unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2i64, 3][..]);
        assert_eq!(l.to_vec::<f32>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 8])
                .is_err()
        );
    }

    #[test]
    fn type_mismatch_rejected() {
        let l = Literal::scalar(1i32);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline"));
    }

    #[test]
    fn hlo_text_requires_file() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
