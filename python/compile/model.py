"""Layer-2: the PMGNS model (paper §3.4) and its Table-4 baseline variants.

Everything here is *functional* so it AOT-lowers cleanly:
  - params are a flat, ordered list of arrays (order defined by param_spec();
    the same order is written to the manifest and used by the Rust runtime),
  - the Adam optimizer update runs INSIDE the train-step HLO, so the Rust
    driver only shuttles literals (params, m, v) between steps,
  - dropout derives its randomness from a seed input via threefry, in-graph.

Architecture (paper Fig. 2): 3 message-passing blocks -> masked-mean readout
-> concat static features F_s -> 3 FC blocks (+dropout) -> linear head with
3 outputs (latency, memory, energy). Targets arrive normalized (log1p +
z-score, computed in Rust); the Huber loss (Table 3) acts in that space.
"""

import functools

import jax
import jax.numpy as jnp

from . import constants as C
from .kernels import fc_block, huber_ref
from .layers import gat, gcn, gin, masked_mean, mlp_node, sage

# ---------------------------------------------------------------------------
# Parameter specifications
# ---------------------------------------------------------------------------


def param_spec(variant: str, hidden: int = None, node_feats: int = None):
    """Ordered [(name, shape)] for a variant. This order IS the ABI between
    the HLO artifacts and the Rust runtime — never reorder without re-lowering.
    """
    h = hidden or C.HIDDEN
    f = node_feats or C.NODE_FEATS
    dims = [(f, h), (h, h), (h, h)]  # 3 message-passing blocks
    spec = []
    for i, (din, dout) in enumerate(dims):
        if variant == "sage":
            spec += [
                (f"sage{i}.w_self", (din, dout)),
                (f"sage{i}.w_neigh", (din, dout)),
                (f"sage{i}.b", (dout,)),
            ]
        elif variant == "gcn":
            spec += [(f"gcn{i}.w", (din, dout)), (f"gcn{i}.b", (dout,))]
        elif variant == "gin":
            spec += [
                (f"gin{i}.eps", ()),
                (f"gin{i}.w1", (din, dout)),
                (f"gin{i}.b1", (dout,)),
                (f"gin{i}.w2", (dout, dout)),
                (f"gin{i}.b2", (dout,)),
            ]
        elif variant == "gat":
            spec += [
                (f"gat{i}.w", (din, dout)),
                (f"gat{i}.a_src", (dout,)),
                (f"gat{i}.a_dst", (dout,)),
                (f"gat{i}.b", (dout,)),
            ]
        elif variant == "mlp":
            spec += [(f"mlp{i}.w", (din, dout)), (f"mlp{i}.b", (dout,))]
        else:
            raise ValueError(f"unknown variant {variant!r}")
    # Shared head: 3 FC blocks + linear regression head (paper Fig. 2).
    spec += [
        ("fc0.w", (h + C.STATIC_FEATS, h)),
        ("fc0.b", (h,)),
        ("fc1.w", (h, h)),
        ("fc1.b", (h,)),
        ("fc2.w", (h, h)),
        ("fc2.b", (h,)),
        ("head.w", (h, C.TARGETS)),
        ("head.b", (C.TARGETS,)),
    ]
    return spec


def init_params(variant: str, seed, hidden: int = None, node_feats: int = None):
    """Glorot-uniform init, traced on a seed scalar (lowered as `init` HLO)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(variant, hidden, node_feats):
        key, sub = jax.random.split(key)
        if name.endswith(".eps"):
            params.append(jnp.zeros(shape, jnp.float32))
        elif len(shape) == 2:
            limit = jnp.sqrt(6.0 / (shape[0] + shape[1]))
            params.append(
                jax.random.uniform(sub, shape, jnp.float32, -limit, limit)
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _backbone(variant, p, x, a_hat, mask, i0=0):
    """Run the 3 message-passing blocks; returns (h, next_param_index)."""
    h, i = x, i0
    for _ in range(3):
        if variant == "sage":
            h = sage(h, a_hat, p[i], p[i + 1], p[i + 2])
            i += 3
        elif variant == "gcn":
            h = gcn(h, a_hat, p[i], p[i + 1])
            i += 2
        elif variant == "gin":
            h = gin(h, a_hat, p[i], p[i + 1], p[i + 2], p[i + 3], p[i + 4])
            i += 5
        elif variant == "gat":
            h = gat(h, a_hat, mask, p[i], p[i + 1], p[i + 2], p[i + 3])
            i += 4
        elif variant == "mlp":
            h = mlp_node(h, p[i], p[i + 1])
            i += 2
        h = h * mask[:, :, None]  # re-assert the padding invariant per block
    return h, i


def forward(variant, params, x, a_hat, statics, mask, *, train=False, seed=None):
    """Full PMGNS forward. Returns [B, TARGETS] in normalized target space."""
    p = list(params)
    h, i = _backbone(variant, p, x, a_hat, mask)
    z = masked_mean(h, mask)  # graph embedding (paper §3.4)
    z = jnp.concatenate([z, statics], axis=1)  # ⊕ F_s (paper eq. 1)
    key = jax.random.PRNGKey(seed) if train else None
    for blk in range(3):
        w, b = p[i], p[i + 1]
        i += 2
        z = fc_block(z, w, b, True) if variant == "sage" else jnp.maximum(z @ w + b, 0.0)
        if train and C.DROPOUT > 0.0:
            key, sub = jax.random.split(key)
            keep = jax.random.bernoulli(sub, 1.0 - C.DROPOUT, z.shape)
            z = jnp.where(keep, z / (1.0 - C.DROPOUT), 0.0)
    w, b = p[i], p[i + 1]
    return z @ w + b  # linear regression head


# ---------------------------------------------------------------------------
# Loss + Adam-in-graph training step
# ---------------------------------------------------------------------------


def loss_fn(variant, params, batch, seed, *, loss="huber"):
    x, a_hat, statics, mask, y = batch
    pred = forward(variant, params, x, a_hat, statics, mask, train=True, seed=seed)
    if loss == "huber":
        return huber_ref(pred, y, C.HUBER_DELTA)
    return jnp.mean((pred - y) ** 2)  # MSE ablation (paper §4.3 mentions it)


def make_train_step(variant, *, loss="huber", n_params=None):
    """Returns train_step(params.., m.., v.., step, lr, seed, X, A, S, mask, Y)
    -> (params'.., m'.., v'.., loss). Flat positional signature for AOT."""
    n = n_params or len(param_spec(variant))

    def train_step(*args):
        params = args[:n]
        m = args[n : 2 * n]
        v = args[2 * n : 3 * n]
        step, lr, seed = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        batch = args[3 * n + 3 :]
        lval, grads = jax.value_and_grad(
            lambda ps: loss_fn(variant, ps, batch, seed, loss=loss)
        )(params)
        t = step + 1.0
        bc1 = 1.0 - C.ADAM_B1**t
        bc2 = 1.0 - C.ADAM_B2**t
        new_p, new_m, new_v = [], [], []
        for pi, mi, vi, gi in zip(params, m, v, grads):
            mi = C.ADAM_B1 * mi + (1.0 - C.ADAM_B1) * gi
            vi = C.ADAM_B2 * vi + (1.0 - C.ADAM_B2) * gi * gi
            update = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + C.ADAM_EPS)
            new_p.append(pi - update)
            new_m.append(mi)
            new_v.append(vi)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (lval,)

    return train_step


def make_predict(variant, *, n_params=None):
    """Returns predict(params.., X, A, S, mask) -> yhat [B, TARGETS]."""
    n = n_params or len(param_spec(variant))

    def predict(*args):
        params = args[:n]
        x, a_hat, statics, mask = args[n : n + 4]
        return (forward(variant, params, x, a_hat, statics, mask, train=False),)

    return predict
