"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from .fc_block import fc_block, fc_block_fwd_pallas
from .ref import fc_block_ref, huber_ref, masked_mean_ref, sage_layer_ref
from .sage_layer import sage_layer, sage_layer_fwd_pallas

__all__ = [
    "fc_block",
    "fc_block_fwd_pallas",
    "fc_block_ref",
    "huber_ref",
    "masked_mean_ref",
    "sage_layer",
    "sage_layer_fwd_pallas",
    "sage_layer_ref",
]
