"""Pallas kernel: fused GraphSAGE layer (the DIPPM compute hot-spot).

One grid step processes one graph of the minibatch and computes

    out = relu(H @ W_self + (A_hat @ H) @ W_neigh + b)

entirely in VMEM: the [N,N] @ [N,F] neighbourhood aggregation and both dense
transforms are fused into a single kernel, so the aggregated features never
round-trip to HBM between the two matmuls — the fusion a GPU implementation
gets from a hand-written CUDA kernel, expressed here with BlockSpec.

TPU mapping (DESIGN.md §7): with N = 160, F = 32..128 the per-step working
set is A-tile (N*N*4 ≈ 100 KB) + H-tile + weights + accumulator ≈ < 1 MB,
far under VMEM; all three matmuls are MXU work. The grid streams graphs
(batch dimension) while the weight blocks are reused across steps (their
index_map is constant), which is exactly the reuse a GPU kernel gets from
caching weights in shared memory across threadblocks.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the same schedule to plain HLO so the
artifact runs on the Rust CPU client (and numerics are identical).

Autodiff: pallas_call has no general VJP, so `sage_layer` carries a
custom_vjp whose backward is plain jnp (see ref.py) — the backward is
bandwidth-bound and XLA fuses it well; the forward is the serving hot path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import sage_layer_ref


def _sage_kernel(h_ref, a_ref, ws_ref, wn_ref, b_ref, o_ref, *, activate):
    """One *batch tile* per grid step; everything lives in VMEM.

    Perf note (EXPERIMENTS.md §Perf/L1): the first version used
    grid=(batch,) with one graph per step. Interpret-mode lowering turns
    the grid into a serial XLA while-loop, so a b=32 call cost ~70x a b=1
    call and dominated the serving hot path. Processing the whole batch
    tile as batched dot_generals in ONE grid step lets XLA emit parallel
    batched matmuls instead (b=32 predict: 240ms -> see EXPERIMENTS.md),
    and on a real TPU it is the better schedule too: the batched
    [Bt,N,N]x[Bt,N,F] contraction keeps the MXU busy across the batch
    while W_self/W_neigh stay resident in VMEM.
    """
    h = h_ref[...]  # [Bt, N, F] batch tile
    a = a_ref[...]  # [Bt, N, N]
    # Batched neighbourhood aggregation on the MXU: [Bt,N,N] @ [Bt,N,F].
    agg = jax.lax.dot_general(
        a, h, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    # Fused self + neighbour transforms: two [Bt,N,F] @ [F,H] contractions.
    out = (
        jax.lax.dot_general(
            h, ws_ref[...], (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + jax.lax.dot_general(
            agg, wn_ref[...], (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + b_ref[...]
    )
    if activate:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out


def _batch_tile(batch: int, n: int, f: int, hidden: int) -> int:
    """Largest batch tile whose working set fits a 16 MB VMEM budget."""
    per_graph = 4 * (n * f + n * n + 2 * n * hidden)  # H + Â + agg + out
    weights = 4 * (2 * f * hidden + hidden)
    budget = 14 * 1024 * 1024  # leave headroom under 16 MB
    tile = max(1, (budget - weights) // per_graph)
    # Prefer a tile that divides the batch evenly.
    tile = min(tile, batch)
    while batch % tile:
        tile -= 1
    return tile


def sage_layer_fwd_pallas(h, a_hat, w_self, w_neigh, b, *, activate=True):
    """Raw Pallas forward. h [B,N,F], a_hat [B,N,N] -> [B,N,H]."""
    batch, n, f = h.shape
    hidden = w_self.shape[1]
    bt = _batch_tile(batch, n, f, hidden)
    kernel = functools.partial(_sage_kernel, activate=activate)
    return pl.pallas_call(
        kernel,
        grid=(batch // bt,),
        in_specs=[
            pl.BlockSpec((bt, n, f), lambda i: (i, 0, 0)),  # H batch tile
            pl.BlockSpec((bt, n, n), lambda i: (i, 0, 0)),  # A_hat tile
            pl.BlockSpec((f, hidden), lambda i: (0, 0)),  # W_self: reused
            pl.BlockSpec((f, hidden), lambda i: (0, 0)),  # W_neigh: reused
            pl.BlockSpec((hidden,), lambda i: (0,)),  # bias: reused
        ],
        out_specs=pl.BlockSpec((bt, n, hidden), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n, hidden), jnp.float32),
        interpret=True,
    )(h, a_hat, w_self, w_neigh, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def sage_layer(h, a_hat, w_self, w_neigh, b, activate=True):
    """GraphSAGE layer: Pallas forward, jnp backward (see module docstring)."""
    return sage_layer_fwd_pallas(h, a_hat, w_self, w_neigh, b, activate=activate)


def _sage_vjp_fwd(h, a_hat, w_self, w_neigh, b, activate):
    out = sage_layer_fwd_pallas(h, a_hat, w_self, w_neigh, b, activate=activate)
    return out, (h, a_hat, w_self, w_neigh, out)


def _sage_vjp_bwd(activate, res, g):
    h, a_hat, w_self, w_neigh, out = res
    if activate:
        g = g * (out > 0.0)
    agg = jnp.einsum("bnm,bmf->bnf", a_hat, h)
    # d(pre) / d inputs for pre = h @ Ws + (A h) @ Wn + b
    d_h = g @ w_self.T + jnp.einsum("bmn,bmh->bnh", a_hat, g @ w_neigh.T)
    d_a = jnp.einsum("bnh,bmh->bnm", g @ w_neigh.T, h)
    d_ws = jnp.einsum("bnf,bnh->fh", h, g)
    d_wn = jnp.einsum("bnf,bnh->fh", agg, g)
    d_b = g.sum(axis=(0, 1))
    return d_h, d_a, d_ws, d_wn, d_b


sage_layer.defvjp(_sage_vjp_fwd, _sage_vjp_bwd)


def sage_layer_checked(h, a_hat, w_self, w_neigh, b, *, activate=True):
    """Reference-checked wrapper used only in tests."""
    return sage_layer_ref(h, a_hat, w_self, w_neigh, b, activate=activate)
