"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest asserts the Pallas kernels
(interpret=True) match these to float32 tolerance over hypothesis-driven
shape sweeps. They are also used as the backward-pass building blocks in the
custom_vjp rules (the hot forward runs the Pallas kernel, the backward is
plain jnp — standard practice, and the backward is bandwidth-bound anyway).
"""

import jax.numpy as jnp


def sage_layer_ref(h, a_hat, w_self, w_neigh, b, *, activate=True):
    """GraphSAGE layer on a padded dense graph.

    h       [B, N, F]   node features
    a_hat   [B, N, N]   row-normalized adjacency (mean aggregator folded in)
    w_self  [F, H]
    w_neigh [F, H]
    b       [H]
    returns [B, N, H]
    """
    agg = jnp.einsum("bnm,bmf->bnf", a_hat, h)
    out = h @ w_self + agg @ w_neigh + b
    return jnp.maximum(out, 0.0) if activate else out


def fc_block_ref(x, w, b, *, activate=True):
    """Fully-connected block: x[B, D_in] @ w[D_in, D_out] + b, optional ReLU."""
    out = x @ w + b
    return jnp.maximum(out, 0.0) if activate else out


def masked_mean_ref(h, mask):
    """Graph readout: mean over valid nodes. h [B,N,H], mask [B,N] -> [B,H]."""
    num = jnp.einsum("bnh,bn->bh", h, mask)
    den = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    return num / den


def huber_ref(pred, target, delta=1.0):
    """Mean Huber loss (paper Table 3)."""
    err = pred - target
    abs_err = jnp.abs(err)
    quad = jnp.minimum(abs_err, delta)
    return jnp.mean(0.5 * quad**2 + delta * (abs_err - quad))
