"""Pallas kernel: fused fully-connected block (dense + bias + optional ReLU).

The DIPPM head is three FC blocks (paper Fig. 2); at serving time they run
back-to-back on small [B, D] activations, so kernel-launch and HBM traffic
dominate. Fusing bias+activation into the matmul kernel removes two
elementwise passes per block.

Grid: single step — the whole [B,D_in] x [D_in,D_out] product fits in VMEM
for every shape DIPPM uses (B <= 32, D <= 512: < 300 KB). For larger D this
would tile over D_out; BlockSpec already expresses that extension.

interpret=True for CPU-PJRT executability; custom_vjp as in sage_layer.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fc_kernel(x_ref, w_ref, b_ref, o_ref, *, activate):
    out = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    out = out + b_ref[...]
    if activate:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out


def fc_block_fwd_pallas(x, w, b, *, activate=True):
    batch, d_in = x.shape
    d_out = w.shape[1]
    kernel = functools.partial(_fc_kernel, activate=activate)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((batch, d_in), lambda i: (0, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((batch, d_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d_out), jnp.float32),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fc_block(x, w, b, activate=True):
    """Fused dense+bias+ReLU: Pallas forward, jnp backward."""
    return fc_block_fwd_pallas(x, w, b, activate=activate)


def _fc_vjp_fwd(x, w, b, activate):
    out = fc_block_fwd_pallas(x, w, b, activate=activate)
    return out, (x, w, out)


def _fc_vjp_bwd(activate, res, g):
    x, w, out = res
    if activate:
        g = g * (out > 0.0)
    return g @ w.T, x.T @ g, g.sum(axis=0)


fc_block.defvjp(_fc_vjp_fwd, _fc_vjp_bwd)
