"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest for Rust.

Run once via `make artifacts` (python -m compile.aot --out-dir ../artifacts).
Python never runs again after this: the Rust runtime loads the HLO text via
`HloModuleProto::from_text_file`, compiles on the PJRT CPU client, and
executes it on the request path.

Interchange format is HLO *text*, not `.serialize()`: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per variant (sage, gcn, gin, gat, mlp):
    {v}_init.hlo.txt          seed:i32            -> params tuple
    {v}_train.hlo.txt         params,m,v,step,lr,seed,X,A,S,mask,Y
                                                  -> params',m',v',loss
    {v}_predict_b{B}.hlo.txt  params,X,A,S,mask   -> (yhat,)
plus sage_train_mse.hlo.txt for the Huber-vs-MSE ablation, and
manifest.json describing shapes, parameter order and input layout.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import constants as C
from .model import init_params, make_predict, make_train_step, param_spec


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(batch):
    """(X, A, S, mask) input specs for a given minibatch size."""
    return [
        _spec((batch, C.MAX_NODES, C.NODE_FEATS)),
        _spec((batch, C.MAX_NODES, C.MAX_NODES)),
        _spec((batch, C.STATIC_FEATS)),
        _spec((batch, C.MAX_NODES)),
    ]


def lower_variant(variant: str, out_dir: str, *, progress=print):
    spec = param_spec(variant)
    n = len(spec)
    pspecs = [_spec(s) for _, s in spec]
    entry = {
        "params": [{"name": name, "shape": list(shape)} for name, shape in spec],
        "predict": {},
    }

    progress(f"  {variant}: init ({n} params)")
    lowered = jax.jit(lambda seed: init_params(variant, seed), keep_unused=True).lower(
        _spec((), jnp.int32)
    )
    fname = f"{variant}_init.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(to_hlo_text(lowered))
    entry["init"] = fname

    losses = ("huber", "mse") if variant == "sage" else ("huber",)
    for loss in losses:
        progress(f"  {variant}: train_step [{loss}]")
        step_fn = make_train_step(variant, loss=loss, n_params=n)
        args = (
            pspecs  # params
            + pspecs  # adam m
            + pspecs  # adam v
            + [_spec(()), _spec(()), _spec((), jnp.int32)]  # step, lr, seed
            + batch_specs(C.BATCH)
            + [_spec((C.BATCH, C.TARGETS))]  # Y
        )
        lowered = jax.jit(step_fn, keep_unused=True).lower(*args)
        fname = f"{variant}_train{'' if loss == 'huber' else '_' + loss}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        entry["train" if loss == "huber" else "train_mse"] = fname

    for b in sorted(set(C.PREDICT_BATCHES)):
        progress(f"  {variant}: predict b{b}")
        pred_fn = make_predict(variant, n_params=n)
        lowered = jax.jit(pred_fn, keep_unused=True).lower(*(pspecs + batch_specs(b)))
        fname = f"{variant}_predict_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        entry["predict"][str(b)] = fname

    return entry


def build_manifest(variants_entries):
    return {
        "constants": {
            "max_nodes": C.MAX_NODES,
            "node_feats": C.NODE_FEATS,
            "static_feats": C.STATIC_FEATS,
            "targets": C.TARGETS,
            "batch": C.BATCH,
            "hidden": C.HIDDEN,
            "dropout": C.DROPOUT,
            "huber_delta": C.HUBER_DELTA,
        },
        # Input layout contracts, mirrored by rust/src/runtime/artifacts.rs.
        "train_inputs": "params*, m*, v*, step:f32, lr:f32, seed:i32, "
        "X[B,N,F], A[B,N,N], S[B,5], mask[B,N], Y[B,3]",
        "predict_inputs": "params*, X[B,N,F], A[B,N,N], S[B,5], mask[B,N]",
        "variants": variants_entries,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants", default=",".join(C.VARIANTS), help="comma-separated subset"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = {}
    for variant in args.variants.split(","):
        print(f"lowering {variant} ...")
        entries[variant] = lower_variant(variant, args.out_dir)

    manifest = build_manifest(entries)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
