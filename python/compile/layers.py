"""Message-passing layers for the PMGNS variants compared in paper Table 4.

All layers operate on the padded dense-graph encoding (DESIGN.md §5):
    h      [B, N, D]  node features (zero-padded past the node mask)
    a_hat  [B, N, N]  row-normalized adjacency with self-loops (zero-padded)
    mask   [B, N]     1.0 for valid operator nodes

The GraphSAGE layer is the paper's pick and runs as the L1 Pallas kernel
(kernels/sage_layer.py). GCN / GIN / GAT / MLP are the baselines; they are
plain jnp — they exist to reproduce the comparison, not to be the hot path.

Zero-padding invariant: every layer must map padded-zero rows to zeros (or
at least to values that the masked-mean readout ignores); tests assert
predictions are invariant to the padding region's contents.
"""

import jax.numpy as jnp

from .kernels import sage_layer


def sage(h, a_hat, w_self, w_neigh, b, *, activate=True):
    """GraphSAGE with mean aggregator (Hamilton et al.) — Pallas forward."""
    return sage_layer(h, a_hat, w_self, w_neigh, b, activate)


def gcn(h, a_hat, w, b, *, activate=True):
    """Kipf & Welling GCN layer: relu(Â h W + b)."""
    out = jnp.einsum("bnm,bmd->bnd", a_hat, h) @ w + b
    return jnp.maximum(out, 0.0) if activate else out


def gin(h, a_hat, eps, w1, b1, w2, b2, *, activate=True):
    """GIN (Xu et al.): MLP((1+eps)·h + agg(h)).

    The canonical GIN uses sum aggregation; on the padded dense encoding we
    aggregate with Â (mean) so padded rows stay zero — the degree information
    GIN would get from sums is already present in the node features
    (DESIGN.md §5). eps is a learned scalar, broadcast.
    """
    agg = jnp.einsum("bnm,bmd->bnd", a_hat, h)
    pre = (1.0 + eps) * h + agg
    hid = jnp.maximum(pre @ w1 + b1, 0.0)
    out = hid @ w2 + b2
    return jnp.maximum(out, 0.0) if activate else out


def gat(h, a_hat, mask, w, a_src, a_dst, b, *, activate=True):
    """Single-head GAT (Veličković et al.) with masked dense attention.

    Attention logits e_ij = LeakyReLU(s_i + d_j) are computed for every
    (i, j) pair, then masked to the edge set (a_hat > 0 — which includes
    self-loops) and to valid target nodes before the softmax.
    """
    hw = h @ w  # [B, N, H]
    s = hw @ a_src  # [B, N]
    d = hw @ a_dst  # [B, N]
    logits = s[:, :, None] + d[:, None, :]  # [B, N, N] (i attends over j)
    logits = jnp.where(logits > 0.0, logits, 0.2 * logits)  # LeakyReLU(0.2)
    edge = (a_hat > 0.0) & (mask[:, None, :] > 0.0)
    logits = jnp.where(edge, logits, -1e9)
    att = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    att = att * edge  # rows with no edges (padding) become all-zero
    att = att / jnp.maximum(att.sum(axis=-1, keepdims=True), 1e-9)
    out = jnp.einsum("bnm,bmh->bnh", att, hw) + b
    out = out * mask[:, :, None]  # keep padded rows exactly zero
    return jnp.maximum(out, 0.0) if activate else out


def mlp_node(h, w, b, *, activate=True):
    """Per-node dense layer — the no-GNN baseline's 'message passing'."""
    out = h @ w + b
    return jnp.maximum(out, 0.0) if activate else out


def masked_mean(h, mask):
    """Graph readout: mean over valid nodes. [B,N,H] x [B,N] -> [B,H]."""
    num = jnp.einsum("bnh,bn->bh", h, mask)
    den = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    return num / den
