"""Shared shape constants for the AOT artifacts.

These are the *compile-time* shapes every HLO artifact is specialized to.
They are written into artifacts/manifest.json by aot.py and parsed by the
Rust runtime — Rust never hard-codes them.

Environment overrides (DIPPM_*) exist so tests and the bench harness can
lower small variants quickly; the defaults are the reproduction profile
described in DESIGN.md §5.
"""

import os


def _env_int(name: str, default: int) -> int:
    v = os.environ.get(name)
    return int(v) if v else default


# Padded graph encoding ------------------------------------------------------
MAX_NODES = _env_int("DIPPM_MAX_NODES", 160)  # N: operator nodes per graph
NODE_FEATS = _env_int("DIPPM_NODE_FEATS", 36)  # F: paper §3.2's 32 + 4-wide dtype one-hot
STATIC_FEATS = 9  # F_s: MACs, batch, #conv, #dense, #relu (paper eq. 1) + 4 dtype counts
TARGETS = 3  # latency (ms), memory (MB), energy (J)

# Model / training -----------------------------------------------------------
HIDDEN = _env_int("DIPPM_HIDDEN", 128)  # paper uses 512; CPU profile uses 128
BATCH = _env_int("DIPPM_BATCH", 32)  # training minibatch
PREDICT_BATCHES = (1, BATCH)  # predict artifacts lowered for these batch sizes
DROPOUT = 0.05  # paper Table 3
HUBER_DELTA = 1.0
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

VARIANTS = ("sage", "gcn", "gin", "gat", "mlp")  # paper Table 4
