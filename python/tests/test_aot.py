"""AOT lowering: HLO text artifacts are well-formed and manifest-consistent."""

import json
import os
import tempfile

import pytest

from compile import constants as C
from compile.aot import batch_specs, build_manifest, lower_variant, to_hlo_text
from compile.model import param_spec

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_lower_small_variant(self):
        """Lower one variant into a temp dir and sanity-check the HLO text."""
        with tempfile.TemporaryDirectory() as d:
            entry = lower_variant("mlp", d, progress=lambda *_: None)
            for key in ("init", "train"):
                path = os.path.join(d, entry[key])
                text = open(path).read()
                assert "ENTRY" in text and "HloModule" in text
            assert set(entry["predict"]) == {str(b) for b in set(C.PREDICT_BATCHES)}

    def test_to_hlo_text_roundtrippable_ids(self):
        """The text must not be a serialized proto (the 64-bit-id trap)."""
        import jax
        import jax.numpy as jnp

        lowered = jax.jit(lambda x: (x + 1.0,)).lower(
            jax.ShapeDtypeStruct((2,), jnp.float32)
        )
        text = to_hlo_text(lowered)
        assert text.lstrip().startswith("HloModule")

    def test_batch_specs_shapes(self):
        x, a, s, mask = batch_specs(7)
        assert x.shape == (7, C.MAX_NODES, C.NODE_FEATS)
        assert a.shape == (7, C.MAX_NODES, C.MAX_NODES)
        assert s.shape == (7, C.STATIC_FEATS)
        assert mask.shape == (7, C.MAX_NODES)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    """Validate the artifacts/ directory the Rust runtime will consume."""

    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_constants_match(self, manifest):
        c = manifest["constants"]
        assert c["max_nodes"] == C.MAX_NODES
        assert c["node_feats"] == C.NODE_FEATS
        assert c["static_feats"] == C.STATIC_FEATS
        assert c["targets"] == C.TARGETS
        assert c["batch"] == C.BATCH

    def test_all_variants_present(self, manifest):
        assert set(manifest["variants"]) == set(C.VARIANTS)

    def test_param_specs_match_model(self, manifest):
        for variant, entry in manifest["variants"].items():
            spec = param_spec(variant)
            assert [(p["name"], tuple(p["shape"])) for p in entry["params"]] == [
                (n, tuple(s)) for n, s in spec
            ]

    def test_artifact_files_exist_and_parse(self, manifest):
        for entry in manifest["variants"].values():
            files = [entry["init"], entry["train"], *entry["predict"].values()]
            if "train_mse" in entry:
                files.append(entry["train_mse"])
            for fname in files:
                path = os.path.join(ART, fname)
                assert os.path.exists(path), fname
                head = open(path).read(200)
                assert head.lstrip().startswith("HloModule"), fname

    def test_sage_has_mse_ablation(self, manifest):
        assert "train_mse" in manifest["variants"]["sage"]
