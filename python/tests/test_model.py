"""L2 correctness: PMGNS variants, padding invariance, Adam-in-graph step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import constants as C
from compile.model import (
    forward,
    init_params,
    loss_fn,
    make_predict,
    make_train_step,
    param_spec,
)

B, N, F, H = 4, 12, C.NODE_FEATS, 16


def _batch(seed=0, b=B, n=N):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, n, F))
    a = jnp.abs(jax.random.normal(ks[1], (b, n, n)))
    a = a / jnp.maximum(a.sum(-1, keepdims=True), 1e-9)
    s = jax.random.normal(ks[2], (b, C.STATIC_FEATS))
    mask = jnp.ones((b, n))
    y = jax.random.normal(ks[3], (b, C.TARGETS))
    return x, a, s, mask, y


def _params(variant):
    return [
        jax.random.normal(jax.random.PRNGKey(i), shape) * 0.1
        for i, (_, shape) in enumerate(param_spec(variant, hidden=H, node_feats=F))
    ]


def _fwd(variant, params, batch, **kw):
    x, a, s, mask, _ = batch
    return forward(variant, params, x, a, s, mask, **kw)


class TestParamSpec:
    @pytest.mark.parametrize("variant", C.VARIANTS)
    def test_spec_names_unique_and_ordered(self, variant):
        spec = param_spec(variant)
        names = [n for n, _ in spec]
        assert len(names) == len(set(names))
        assert names[-1] == "head.b"  # regression head is always last

    @pytest.mark.parametrize("variant", C.VARIANTS)
    def test_init_matches_spec(self, variant):
        params = init_params(variant, 0)
        spec = param_spec(variant)
        assert len(params) == len(spec)
        for p, (_, shape) in zip(params, spec):
            assert p.shape == shape
            assert p.dtype == jnp.float32

    def test_init_is_seed_deterministic(self):
        a = init_params("sage", 7)
        b = init_params("sage", 7)
        c = init_params("sage", 8)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
        assert any(not np.array_equal(x, z) for x, z in zip(a, c))


class TestForward:
    @pytest.mark.parametrize("variant", C.VARIANTS)
    def test_output_shape(self, variant):
        out = _fwd(variant, _params(variant), _batch())
        assert out.shape == (B, C.TARGETS)
        assert bool(jnp.all(jnp.isfinite(out)))

    @pytest.mark.parametrize("variant", C.VARIANTS)
    def test_padding_invariance(self, variant):
        """Garbage node features/adjacency beyond the mask must not change
        predictions — the core invariant of the padded-graph encoding."""
        x, a, s, mask, y = _batch()
        valid = 7
        mask = mask.at[:, valid:].set(0.0)
        x = x * mask[:, :, None]
        a = a * mask[:, :, None] * mask[:, None, :]
        base = forward(variant, _params(variant), x, a, s, mask)
        x2 = x.at[:, valid:].set(123.0)
        a2 = a.at[:, valid:, valid:].set(0.5)
        pert = forward(variant, _params(variant), x2, a2, s, mask)
        np.testing.assert_allclose(base, pert, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("variant", ["sage", "gcn", "gat"])
    def test_adjacency_matters(self, variant):
        """GNN variants must actually read the graph structure."""
        x, a, s, mask, _ = _batch()
        p = _params(variant)
        out1 = forward(variant, p, x, a, s, mask)
        a2 = jnp.zeros_like(a)
        out2 = forward(variant, p, x, a2, s, mask)
        assert not np.allclose(out1, out2, rtol=1e-3)

    def test_mlp_ignores_adjacency(self):
        x, a, s, mask, _ = _batch()
        p = _params("mlp")
        out1 = forward("mlp", p, x, a, s, mask)
        out2 = forward("mlp", p, x, jnp.zeros_like(a), s, mask)
        np.testing.assert_allclose(out1, out2, rtol=1e-6)

    def test_static_features_matter(self):
        x, a, s, mask, _ = _batch()
        p = _params("sage")
        out1 = forward("sage", p, x, a, s, mask)
        out2 = forward("sage", p, x, a, s + 1.0, mask)
        assert not np.allclose(out1, out2, rtol=1e-3)

    def test_dropout_train_vs_eval(self):
        x, a, s, mask, _ = _batch()
        p = _params("sage")
        e1 = forward("sage", p, x, a, s, mask, train=False)
        e2 = forward("sage", p, x, a, s, mask, train=False)
        np.testing.assert_array_equal(e1, e2)  # eval is deterministic
        t1 = forward("sage", p, x, a, s, mask, train=True, seed=0)
        t2 = forward("sage", p, x, a, s, mask, train=True, seed=1)
        assert not np.array_equal(np.asarray(t1), np.asarray(t2))


class TestTrainStep:
    @pytest.mark.parametrize("variant", C.VARIANTS)
    def test_loss_decreases(self, variant):
        spec = param_spec(variant, hidden=H, node_feats=F)
        n = len(spec)
        params = _params(variant)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        batch = _batch()
        step = jax.jit(make_train_step(variant, n_params=n))
        first = None
        out = None
        for i in range(30):
            args = (
                tuple(params)
                + tuple(m)
                + tuple(v)
                + (jnp.float32(i), jnp.float32(3e-3), jnp.int32(i))
                + batch
            )
            out = step(*args)
            params, m, v = out[:n], out[n : 2 * n], out[2 * n : 3 * n]
            if first is None:
                first = float(out[-1])
        assert float(out[-1]) < first * 0.9, (variant, first, float(out[-1]))

    def test_adam_matches_reference_implementation(self):
        """One in-graph Adam step == a hand-rolled numpy Adam step."""
        variant = "mlp"
        spec = param_spec(variant, hidden=H, node_feats=F)
        n = len(spec)
        params = _params(variant)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        batch = _batch()
        lr = 1e-3
        # Reference: grads via jax, Adam via numpy. Dropout must be identical,
        # so use the same seed on both sides.
        seed = jnp.int32(3)
        grads = jax.grad(lambda ps: loss_fn(variant, ps, batch, seed))(
            tuple(params)
        )
        step = make_train_step(variant, n_params=n)
        out = step(
            *(
                tuple(params)
                + tuple(m)
                + tuple(v)
                + (jnp.float32(0.0), jnp.float32(lr), seed)
                + batch
            )
        )
        t = 1.0
        for pi, gi, po in zip(params, grads, out[:n]):
            mi = 0.1 * np.asarray(gi)
            vi = 0.001 * np.asarray(gi) ** 2
            upd = lr * (mi / (1 - C.ADAM_B1**t)) / (
                np.sqrt(vi / (1 - C.ADAM_B2**t)) + C.ADAM_EPS
            )
            np.testing.assert_allclose(np.asarray(po), np.asarray(pi) - upd,
                                       rtol=1e-5, atol=1e-6)

    def test_mse_loss_variant(self):
        batch = _batch()
        params = tuple(_params("sage"))
        h = loss_fn("sage", params, batch, jnp.int32(0), loss="huber")
        m = loss_fn("sage", params, batch, jnp.int32(0), loss="mse")
        assert float(h) > 0 and float(m) > 0 and float(h) != float(m)


class TestPredict:
    @pytest.mark.parametrize("variant", C.VARIANTS)
    def test_predict_returns_tuple(self, variant):
        spec = param_spec(variant, hidden=H, node_feats=F)
        n = len(spec)
        pred = make_predict(variant, n_params=n)
        x, a, s, mask, _ = _batch()
        (out,) = pred(*(tuple(_params(variant)) + (x, a, s, mask)))
        assert out.shape == (B, C.TARGETS)

    def test_predict_matches_eval_forward(self):
        n = len(param_spec("sage", hidden=H, node_feats=F))
        pred = make_predict("sage", n_params=n)
        batch = _batch()
        p = _params("sage")
        (out,) = pred(*(tuple(p) + batch[:4]))
        want = _fwd("sage", p, batch, train=False)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
