"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

This is the CORE numerics signal for the whole stack: the Rust runtime
executes HLO lowered from these kernels, so kernel == oracle here implies
the serving path computes what the reference math says.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    fc_block,
    fc_block_fwd_pallas,
    fc_block_ref,
    huber_ref,
    masked_mean_ref,
    sage_layer,
    sage_layer_fwd_pallas,
    sage_layer_ref,
)

RTOL, ATOL = 1e-5, 1e-5


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _norm_adj(key, b, n):
    a = jnp.abs(_rand(key, b, n, n))
    return a / jnp.maximum(a.sum(-1, keepdims=True), 1e-9)


# --------------------------------------------------------------------------
# sage_layer
# --------------------------------------------------------------------------


class TestSageLayer:
    @pytest.mark.parametrize("activate", [True, False])
    def test_matches_ref(self, activate):
        b, n, f, h = 3, 12, 8, 16
        x, ws, wn, bb = _rand(0, b, n, f), _rand(1, f, h), _rand(2, f, h), _rand(3, h)
        a = _norm_adj(4, b, n)
        got = sage_layer_fwd_pallas(x, a, ws, wn, bb, activate=activate)
        want = sage_layer_ref(x, a, ws, wn, bb, activate=activate)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 4),
        n=st.integers(1, 24),
        f=st.integers(1, 16),
        h=st.integers(1, 24),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, b, n, f, h, seed):
        """Hypothesis sweep over kernel shapes (system-prompt requirement)."""
        x = _rand(seed, b, n, f)
        a = _norm_adj(seed + 1, b, n)
        ws, wn, bb = _rand(seed + 2, f, h), _rand(seed + 3, f, h), _rand(seed + 4, h)
        got = sage_layer_fwd_pallas(x, a, ws, wn, bb)
        want = sage_layer_ref(x, a, ws, wn, bb)
        assert got.shape == (b, n, h)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_adjacency_is_self_only(self):
        """With Â = 0 the layer degenerates to relu(H @ W_self + b)."""
        b, n, f, h = 2, 6, 4, 8
        x, ws, wn, bb = _rand(0, b, n, f), _rand(1, f, h), _rand(2, f, h), _rand(3, h)
        a = jnp.zeros((b, n, n))
        got = sage_layer_fwd_pallas(x, a, ws, wn, bb)
        want = jnp.maximum(x @ ws + bb, 0.0)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_identity_adjacency_doubles_self(self):
        """Â = I aggregates each node's own features: H@Ws + H@Wn + b."""
        b, n, f, h = 2, 5, 4, 8
        x, ws, wn, bb = _rand(0, b, n, f), _rand(1, f, h), _rand(2, f, h), _rand(3, h)
        a = jnp.broadcast_to(jnp.eye(n), (b, n, n))
        got = sage_layer_fwd_pallas(x, a, ws, wn, bb, activate=False)
        want = x @ ws + x @ wn + bb
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_custom_vjp_matches_jnp_grad(self):
        """Gradients through the Pallas forward == gradients of the oracle."""
        b, n, f, h = 2, 8, 6, 10
        x, ws, wn, bb = _rand(0, b, n, f), _rand(1, f, h), _rand(2, f, h), _rand(3, h)
        a = _norm_adj(4, b, n)

        def via_kernel(x, a, ws, wn, bb):
            return jnp.sum(sage_layer(x, a, ws, wn, bb) ** 2)

        def via_ref(x, a, ws, wn, bb):
            return jnp.sum(sage_layer_ref(x, a, ws, wn, bb) ** 2)

        g1 = jax.grad(via_kernel, argnums=(0, 1, 2, 3, 4))(x, a, ws, wn, bb)
        g2 = jax.grad(via_ref, argnums=(0, 1, 2, 3, 4))(x, a, ws, wn, bb)
        for a1, a2 in zip(g1, g2):
            np.testing.assert_allclose(a1, a2, rtol=1e-4, atol=1e-4)

    def test_jit_compatible(self):
        b, n, f, h = 2, 8, 6, 10
        x, ws, wn, bb = _rand(0, b, n, f), _rand(1, f, h), _rand(2, f, h), _rand(3, h)
        a = _norm_adj(4, b, n)
        got = jax.jit(lambda *a_: sage_layer(*a_))(x, a, ws, wn, bb)
        want = sage_layer_ref(x, a, ws, wn, bb)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# fc_block
# --------------------------------------------------------------------------


class TestFcBlock:
    @pytest.mark.parametrize("activate", [True, False])
    def test_matches_ref(self, activate):
        b, din, dout = 8, 16, 12
        x, w, bb = _rand(0, b, din), _rand(1, din, dout), _rand(2, dout)
        got = fc_block_fwd_pallas(x, w, bb, activate=activate)
        want = fc_block_ref(x, w, bb, activate=activate)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 33),
        din=st.integers(1, 40),
        dout=st.integers(1, 40),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, b, din, dout, seed):
        x, w, bb = _rand(seed, b, din), _rand(seed + 1, din, dout), _rand(seed + 2, dout)
        got = fc_block_fwd_pallas(x, w, bb)
        want = fc_block_ref(x, w, bb)
        assert got.shape == (b, dout)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_custom_vjp_matches_jnp_grad(self):
        b, din, dout = 4, 10, 6
        x, w, bb = _rand(0, b, din), _rand(1, din, dout), _rand(2, dout)
        g1 = jax.grad(lambda *a_: jnp.sum(fc_block(*a_) ** 2), argnums=(0, 1, 2))(
            x, w, bb
        )
        g2 = jax.grad(
            lambda *a_: jnp.sum(fc_block_ref(*a_) ** 2), argnums=(0, 1, 2)
        )(x, w, bb)
        for a1, a2 in zip(g1, g2):
            np.testing.assert_allclose(a1, a2, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# oracles' own invariants
# --------------------------------------------------------------------------


class TestOracles:
    def test_masked_mean_ignores_padding(self):
        h = _rand(0, 2, 6, 4)
        mask = jnp.array([[1, 1, 1, 0, 0, 0], [1, 1, 1, 1, 1, 1]], jnp.float32)
        got = masked_mean_ref(h, mask)
        want0 = h[0, :3].mean(axis=0)
        np.testing.assert_allclose(got[0], want0, rtol=RTOL, atol=ATOL)
        # Garbage in the padding region must not change the readout.
        h2 = h.at[0, 3:].set(1e6)
        got2 = masked_mean_ref(h2, mask)
        np.testing.assert_allclose(got[0], got2[0], rtol=RTOL, atol=ATOL)

    def test_huber_quadratic_small_linear_large(self):
        small = huber_ref(jnp.array([0.5]), jnp.array([0.0]), 1.0)
        np.testing.assert_allclose(small, 0.5 * 0.25, rtol=RTOL)
        large = huber_ref(jnp.array([10.0]), jnp.array([0.0]), 1.0)
        np.testing.assert_allclose(large, 0.5 + 9.0, rtol=RTOL)

    def test_huber_zero_at_perfect_prediction(self):
        y = _rand(0, 5, 3)
        assert float(huber_ref(y, y)) == 0.0
