//! MIG advisor — the paper's §3.5/§4.4 scenario as a standalone tool:
//! for a set of models (seen, partially-seen and unseen families), show
//! per-profile memory/latency on the device simulator, the eq.(2) rule's
//! choice from the 7g.40gb memory bound, and whether it matches the
//! actually-best profile.
//!
//! Run: `cargo run --release --example mig_advisor`

use dippm::mig;
use dippm::modelgen::Family;
use dippm::simulator::{MigResult, Simulator, ALL_PROFILES};
use dippm::util::bench::Table;

fn main() {
    let sim = Simulator::new();
    // Memoizing advisor: repeated advisories for the same architecture
    // (DSE re-queries) are served from its fingerprint-keyed memo.
    let advisor = mig::MigAdvisor::new(sim.clone());
    let models = vec![
        ("seen", Family::DenseNet.generate(3)),
        ("seen", Family::DenseNet.generate(100)),
        ("partially seen", Family::Swin.generate(12)),
        ("partially seen", Family::Swin.generate(60)),
        ("seen", Family::Vgg.generate(200)),
        ("seen", Family::EfficientNet.generate(40)),
        // Deliberate re-query of the first model: a memo hit.
        ("seen (re-query)", Family::DenseNet.generate(3)),
    ];

    for (status, g) in models {
        println!("\n=== {} (batch {}, {status}) ===", g.variant, g.batch);
        let mut t = Table::new(&["profile", "memory (MB)", "mem/capacity", "latency (ms)"]);
        for p in ALL_PROFILES {
            match sim.measure_mig(&g, p) {
                MigResult::Ok(m) => t.row(&[
                    p.name().to_string(),
                    format!("{:.0}", m.memory_mb),
                    format!("{:.0}%", 100.0 * m.memory_mb / p.capacity_mb()),
                    format!("{:.3}", m.latency_ms),
                ]),
                MigResult::OutOfMemory { required_mb, .. } => t.row(&[
                    p.name().to_string(),
                    format!("OOM ({required_mb:.0})"),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
        t.print();
        // The paper's rule: predict from full-GPU memory (upper bound).
        let full_mem = sim.measure(&g).memory_mb;
        let advice = advisor.advise(&g, Some(full_mem));
        let rule = advice.predicted.map(|p| p.name()).unwrap_or("None");
        let actual = advice.table.best.map(|p| p.name()).unwrap_or("None");
        println!(
            "eq.(2) from 7g.40gb memory ({full_mem:.0} MB): {rule}  |  actually best: {actual}  |  {}",
            if rule == actual { "MATCH" } else { "MISS" }
        );
    }
    let (hits, misses) = advisor.memo_stats();
    println!("\nadvisor memo: {hits} hits / {misses} misses (re-queries are free)");
}
