//! Design-space exploration through the sweep verb — the paper's intro
//! use case served by the coordinator: one request ships an EfficientNet
//! base graph plus a mutation grid, and the server expands the
//! width × batch × dtype candidates, dedups them against the prediction
//! cache, streams back chunked latency/energy/memory estimates, and
//! closes with the Pareto frontier plus a fleet-level MIG packing.
//!
//! Run: `cargo run --release --example design_space_exploration`
//!
//! Pass `--client-loop` to run the same grid the old way — expanded
//! client-side, one predict round trip per candidate (the baseline the
//! `sweep_throughput` bench compares against).

use std::sync::{mpsc, Arc};

use dippm::coordinator::{expand, Coordinator, CoordinatorOptions, SweepSpec};
use dippm::ir::DType;
use dippm::modelgen::mobile::efficientnet;
use dippm::util::bench::Table;
use dippm::wire::{reactor, ReactorConfig, WireClient};

/// Start the binary reactor on an ephemeral port; returns its address.
fn serve(coord: Arc<Coordinator>) -> String {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        reactor::serve(coord, "127.0.0.1:0", ReactorConfig::default(), move |p| {
            let _ = tx.send(p);
        })
        .unwrap();
    });
    format!("127.0.0.1:{}", rx.recv().unwrap())
}

fn main() -> anyhow::Result<()> {
    let client_loop = std::env::args().any(|a| a == "--client-loop");
    let coord = Arc::new(Coordinator::start_sim(CoordinatorOptions::default())?);
    let addr = serve(coord);
    let mut client = WireClient::connect(&addr)?;

    // EfficientNet-B0 at batch 16 is the base; the server mutates it.
    let base = efficientnet::build(4, 1);
    let spec = SweepSpec {
        widths: vec![100, 85, 70, 55],
        batches: vec![1, 4, 16, 64],
        dtypes: vec![DType::F32, DType::F16],
        slo_ms: 10.0,
        fleet_gpus: 4,
        ..SweepSpec::default()
    };

    if client_loop {
        // Baseline: the pre-sweep protocol — expand the grid locally and
        // pay one round trip (and one server admission) per candidate.
        let t0 = std::time::Instant::now();
        let cands = expand(&base, &spec);
        let mut ok = 0usize;
        for c in &cands {
            if let Ok(g) = &c.graph {
                if client.predict_graph(g).is_ok() {
                    ok += 1;
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "[client-loop] {ok}/{} candidates in {dt:.2}s ({:.0} cand/s, one round trip each)",
            cands.len(),
            cands.len() as f64 / dt
        );
        return Ok(());
    }

    println!("=== EfficientNet design-space sweep (one round trip) ===\n");
    let t0 = std::time::Instant::now();
    let (items, summary) = client.sweep(&base, None, &spec)?;
    let dt = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&["candidate", "latency (ms)", "energy (J)", "memory (MB)", "cached"]);
    for it in items.iter().take(12) {
        match &it.result {
            Ok(p) => t.row(&[
                it.label.clone(),
                format!("{:.3}", p.latency_ms),
                format!("{:.3}", p.energy_j),
                format!("{:.0}", p.memory_mb),
                if it.cached { "Y".into() } else { "n".into() },
            ]),
            Err(e) => t.row(&[it.label.clone(), e.clone(), "-".into(), "-".into(), "-".into()]),
        }
    }
    t.print();
    if items.len() > 12 {
        println!("  ... {} more candidates", items.len() - 12);
    }
    println!(
        "\n{} candidates in {dt:.2}s ({:.0} cand/s): {} deduped, {} cache hits, {} batches, {} errors",
        summary.candidates,
        summary.candidates as f64 / dt,
        summary.duplicates,
        summary.cache_hits,
        summary.batches,
        summary.errors
    );

    println!("\nServer-computed Pareto frontier (latency, memory, energy):");
    for f in &summary.frontier {
        println!(
            "  {}: {:.3} ms, {:.0} MB, {:.3} J",
            f.label, f.latency_ms, f.memory_mb, f.energy_j
        );
    }

    if let Some(pack) = &summary.packing {
        println!(
            "\nFleet packing: {} placed on {} A100s (SLO {} ms; rejected: {} slo, {} capacity, {} fleet-full)",
            pack.placed.len(),
            pack.gpus,
            pack.slo_ms.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            pack.rejected_slo,
            pack.rejected_capacity,
            pack.rejected_fleet_full
        );
        let mut t = Table::new(&["candidate", "gpu", "MIG slice"]);
        for p in pack.placed.iter().take(12) {
            t.row(&[p.label.clone(), p.gpu.to_string(), p.profile.name().to_string()]);
        }
        t.print();
    }

    // Re-sweep: every distinct grid point answers from the cache now.
    let t0 = std::time::Instant::now();
    let (_, again) = client.sweep(&base, None, &spec)?;
    println!(
        "\nRe-sweep (warm cache): {} hits / {} distinct in {:.3}s, {} new batches",
        again.cache_hits,
        summary.candidates - summary.duplicates,
        t0.elapsed().as_secs_f64(),
        again.batches
    );
    Ok(())
}
