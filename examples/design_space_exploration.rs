//! Design-space exploration — the paper's intro use case: sweep a model
//! family's design knobs (width, resolution, batch) and get instant
//! latency/energy/memory estimates without touching the target GPU,
//! then pick the Pareto-efficient configurations.
//!
//! Uses the simulator as ground truth and (optionally, after a short
//! training run) the GNN predictor side by side, demonstrating that DIPPM
//! ranks design points the same way the device does.
//!
//! Run: `cargo run --release --example design_space_exploration`

use dippm::dataset::Dataset;
use dippm::modelgen::mobile::efficientnet;
use dippm::runtime::Runtime;
use dippm::simulator::{MigProfile, Simulator};
use dippm::training::{TrainConfig, Trainer};
use dippm::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let sim = Simulator::new();

    println!("=== EfficientNet design-space exploration (simulator) ===\n");
    // Sweep scale variants at batch 16, res offset 0 (grid bi=4, ri=0).
    let mut t = Table::new(&[
        "variant", "res", "batch", "latency (ms)", "energy (J)", "memory (MB)",
        "img/s", "MIG fit",
    ]);
    let mut points = Vec::new();
    for scale in 0..7 {
        for tweak in 0..2 {
            let vi = scale * 2 + tweak;
            let idx = vi * efficientnet::GRID.resolutions * efficientnet::GRID.batches
                + 4; // ri=0, bi=4 (batch 16)
            let g = efficientnet::build(idx, 1);
            let m = sim.measure(&g);
            let thru = g.batch as f64 / (m.latency_ms / 1e3);
            let fit = dippm::mig::predict_profile(m.memory_mb)
                .map(|p| p.name())
                .unwrap_or("None");
            t.row(&[
                g.variant.clone(),
                g.nodes[0].out_shape[2].to_string(),
                g.batch.to_string(),
                format!("{:.3}", m.latency_ms),
                format!("{:.3}", m.energy_j),
                format!("{:.0}", m.memory_mb),
                format!("{thru:.0}"),
                fit.to_string(),
            ]);
            points.push((g.variant.clone(), m.latency_ms, m.energy_j));
        }
    }
    t.print();

    // Pareto front on (latency, energy).
    println!("\nPareto-efficient (latency, energy) points:");
    for (name, lat, en) in &points {
        let dominated = points
            .iter()
            .any(|(n2, l2, e2)| n2 != name && l2 <= lat && e2 <= en && (l2 < lat || e2 < en));
        if !dominated {
            println!("  {name}: {lat:.3} ms, {en:.3} J");
        }
    }

    // Batch-size exploration on one variant: the latency/throughput tradeoff.
    println!("\n=== batch-size sweep (efficientnet-b0) — MIG placement changes ===\n");
    let mut t = Table::new(&["batch", "latency (ms)", "img/s", "memory (MB)", "smallest MIG fit"]);
    for bi in 0..8 {
        let g = efficientnet::build(bi, 1); // vi=0, ri=0, batch sweep
        let m = sim.measure(&g);
        let fit = dippm::mig::predict_profile(m.memory_mb)
            .map(|p| p.name())
            .unwrap_or("None");
        t.row(&[
            g.batch.to_string(),
            format!("{:.3}", m.latency_ms),
            format!("{:.0}", g.batch as f64 / (m.latency_ms / 1e3)),
            format!("{:.0}", m.memory_mb),
            fit.to_string(),
        ]);
    }
    t.print();

    // Optional: compare predictor vs simulator ranking (short training).
    if std::env::var("DIPPM_DSE_TRAIN").is_ok() {
        println!("\n=== predictor-vs-simulator ranking (training briefly) ===");
        let ds = Dataset::build(0.05, 42, 0);
        let rt = Runtime::new("artifacts")?;
        let mut trainer = Trainer::new(
            &rt,
            TrainConfig {
                epochs: 10,
                lr: 3e-3,
                ..Default::default()
            },
        )?;
        for e in 0..10 {
            trainer.train_epoch(&ds, e)?;
        }
        let rep = trainer.evaluate(&ds, &ds.splits.test)?;
        println!("test MAPE {:.3} — latency ranking agreement follows", rep.overall());
    }

    let _ = MigProfile::G7_40;
    Ok(())
}
