//! Quickstart — the paper's Fig. 5 usability story, end to end in ~a minute:
//!
//! 1. build a small dataset on the A100 simulator,
//! 2. train the GraphSAGE predictor briefly through the PJRT train artifact,
//! 3. export a VGG16 to the PyTorch exchange format (as a user's model file),
//! 4. predict its latency / memory / energy / MIG profile — without
//!    "running" the model on the target GPU.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use dippm::coordinator::{Coordinator, CoordinatorOptions};
use dippm::dataset::Dataset;
use dippm::frontends::{self, Framework};
use dippm::modelgen::Family;
use dippm::runtime::Runtime;
use dippm::training::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    // 1. Dataset (2% of Table 2 ≈ 210 graphs — quickstart-sized).
    println!("[1/4] building dataset (2% of the paper's 10,508 graphs)...");
    let ds = Dataset::build(0.02, 42, 0);
    println!(
        "      {} graphs, {} train / {} val / {} test",
        ds.len(),
        ds.splits.train.len(),
        ds.splits.val.len(),
        ds.splits.test.len()
    );

    // 2. Train GraphSAGE for a handful of epochs.
    println!("[2/4] training PMGNS (GraphSAGE) via the AOT train artifact...");
    let rt = Runtime::new("artifacts")?;
    let mut trainer = Trainer::new(
        &rt,
        TrainConfig {
            epochs: 8,
            lr: 3e-3,
            ..Default::default()
        },
    )?;
    for epoch in 0..trainer.config.epochs {
        let log = trainer.train_epoch(&ds, epoch)?;
        println!("      epoch {:2}  huber loss {:.4}", epoch, log.mean_loss);
    }
    let val = trainer.evaluate(&ds, &ds.splits.val)?;
    println!(
        "      val MAPE {:.1}% (paper reaches 1.9% at full scale)",
        100.0 * val.overall()
    );

    // 3. A user's model file: VGG16 in the PyTorch exchange format.
    println!("[3/4] exporting vgg16 to the PyTorch format (the user's input)...");
    let vgg16 = Family::Vgg.generate(8 * 32 + 2 * 8 + 3); // vgg16-w64 @224 b8
    let model_file = std::env::temp_dir().join("vgg16_pytorch.json");
    std::fs::write(&model_file, frontends::export(Framework::PyTorch, &vgg16))?;
    println!(
        "      {} ({} nodes, batch {})",
        vgg16.variant,
        vgg16.n_nodes(),
        vgg16.batch
    );

    // 4. Predict through the serving coordinator (paper Fig. 5's API call).
    println!("[4/4] predicting through the coordinator...");
    let params = trainer.params.clone();
    drop(trainer);
    drop(rt); // coordinator owns its own runtime
    let coord = Coordinator::start("artifacts", params, CoordinatorOptions::default())?;
    let content = std::fs::read_to_string(&model_file)?;
    let graph = frontends::parse_any(&content).map_err(|e| anyhow::anyhow!(e))?;
    let pred = coord.predict(graph)?;
    println!();
    println!("  DIPPM prediction for {} (no GPU run needed):", vgg16.variant);
    println!("    latency : {:9.3} ms", pred.latency_ms);
    println!("    memory  : {:9.0} MB", pred.memory_mb);
    println!("    energy  : {:9.3} J", pred.energy_j);
    println!(
        "    MIG     : {}",
        pred.mig_profile.as_deref().unwrap_or("None")
    );
    // Ground truth from the device simulator for comparison:
    let m = dippm::simulator::Simulator::new().measure(&vgg16);
    println!(
        "  simulator ground truth: {:.3} ms, {:.0} MB, {:.3} J",
        m.latency_ms, m.memory_mb, m.energy_j
    );
    std::fs::remove_file(&model_file).ok();
    Ok(())
}
