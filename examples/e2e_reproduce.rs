//! End-to-end reproduction driver (the system-prompt-mandated E2E example):
//! exercises every layer of the stack on a real (simulator-scale) workload —
//!
//!   modelgen → simulator ground truth → dataset (Table 2 distribution)
//!   → featurization (Algorithm 1 + eq. 1) → PJRT training (Pallas SAGE
//!   kernel, Adam-in-HLO) → MAPE on the held-out test split (the paper's
//!   headline metric) → MIG advisory on seen + unseen architectures
//!   → serving coordinator smoke.
//!
//! Environment knobs: DIPPM_E2E_FRACTION (default 0.12), DIPPM_E2E_EPOCHS
//! (default 20). The run is recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_reproduce`

use dippm::coordinator::{Coordinator, CoordinatorOptions};
use dippm::dataset::Dataset;
use dippm::mig;
use dippm::modelgen::Family;
use dippm::runtime::Runtime;
use dippm::simulator::Simulator;
use dippm::training::{TrainConfig, Trainer};
use dippm::util::bench::Table;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let fraction = env_f64("DIPPM_E2E_FRACTION", 0.12);
    let epochs = env_f64("DIPPM_E2E_EPOCHS", 20.0) as usize;
    let t_start = std::time::Instant::now();

    println!("=== DIPPM end-to-end reproduction ===");
    println!("fraction={fraction} epochs={epochs}\n");

    // --- dataset ---------------------------------------------------------
    let t0 = std::time::Instant::now();
    let ds = Dataset::build(fraction, 42, 0);
    println!(
        "[dataset] {} graphs in {:.1}s ({:.0} graphs/s) — Table 2 distribution:",
        ds.len(),
        t0.elapsed().as_secs_f64(),
        ds.len() as f64 / t0.elapsed().as_secs_f64()
    );
    for (family, count) in ds.family_distribution() {
        print!("  {family}:{count}");
    }
    println!("\n");

    // --- training --------------------------------------------------------
    let rt = Runtime::new("artifacts")?;
    let mut trainer = Trainer::new(
        &rt,
        TrainConfig {
            epochs,
            lr: 3e-3,
            seed: 0,
            ..Default::default()
        },
    )?;
    println!("[train] GraphSAGE PMGNS, {} params", trainer.params.total_elements());
    let mut loss_curve = Vec::new();
    for epoch in 0..epochs {
        let log = trainer.train_epoch(&ds, epoch)?;
        loss_curve.push(log.mean_loss);
        if epoch % 5 == 0 || epoch + 1 == epochs {
            let val = trainer.evaluate(&ds, &ds.splits.val)?;
            println!(
                "  epoch {:3}  loss {:.4}  val MAPE {:.4} ({:.1}s/epoch)",
                epoch,
                log.mean_loss,
                val.overall(),
                log.seconds
            );
        }
    }
    println!(
        "  loss curve: {}",
        loss_curve
            .iter()
            .map(|l| format!("{l:.3}"))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // --- headline metric ---------------------------------------------------
    let train_rep = trainer.evaluate(&ds, &ds.splits.train)?;
    let val_rep = trainer.evaluate(&ds, &ds.splits.val)?;
    let test_rep = trainer.evaluate(&ds, &ds.splits.test)?;
    println!("\n[eval] MAPE (paper §4.3: train 0.041 / val 0.023 / test 0.019 @500 epochs):");
    let mut t = Table::new(&["split", "overall", "latency", "memory", "energy", "n"]);
    for (name, r) in [("train", &train_rep), ("val", &val_rep), ("test", &test_rep)] {
        t.row(&[
            name.into(),
            format!("{:.4}", r.overall()),
            format!("{:.4}", r.mape_latency),
            format!("{:.4}", r.mape_memory),
            format!("{:.4}", r.mape_energy),
            r.n.to_string(),
        ]);
    }
    t.print();

    // --- MIG advisory (Table 5 scenario: seen / partially seen / unseen) ---
    println!("\n[mig] predicted vs actual profile:");
    let sim = Simulator::new();
    let mut mig_table = Table::new(&["model", "batch", "pred mem", "pred MIG", "actual mem", "actual MIG", "hit"]);
    let coord_params = trainer.params.clone();
    // Unseen architecture: ConvNeXt-like (not one of the 10 families).
    let convnext = convnext_like(4);
    let candidates = vec![
        Family::DenseNet.generate(3),  // seen family
        Family::DenseNet.generate(27), // seen family, different config
        Family::Swin.generate(5),      // transformer family
        convnext,                      // unseen
    ];
    drop(trainer);
    drop(rt);
    let coord = Coordinator::start("artifacts", coord_params, CoordinatorOptions::default())?;
    for g in candidates {
        let pred = coord.predict(g.clone())?;
        let actual_mem = sim.measure(&g).memory_mb;
        let actual = mig::actual_best_profile(&sim, &g)
            .map(|p| p.name().to_string())
            .unwrap_or("None".into());
        let predicted = pred.mig_profile.clone().unwrap_or("None".into());
        let hit = if predicted == actual { "Y" } else { "n" };
        mig_table.row(&[
            g.variant.clone(),
            g.batch.to_string(),
            format!("{:.0}", pred.memory_mb),
            predicted,
            format!("{actual_mem:.0}"),
            actual,
            hit.into(),
        ]);
    }
    mig_table.print();

    // --- serving smoke ------------------------------------------------------
    let t0 = std::time::Instant::now();
    let n_req = 64;
    let rxs: Vec<_> = (0..n_req)
        .map(|i| coord.submit(Family::MobileNet.generate(i)))
        .collect();
    for rx in rxs {
        rx.recv().unwrap()?;
    }
    let el = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "\n[serve] {n_req} requests in {el:.2}s = {:.1} req/s, mean batch fill {:.1}",
        n_req as f64 / el,
        m.mean_batch_fill()
    );

    println!(
        "\n=== done in {:.1}s — record this run in EXPERIMENTS.md ===",
        t_start.elapsed().as_secs_f64()
    );
    Ok(())
}

/// A ConvNeXt-style block stack — an architecture family DIPPM never saw
/// in training (paper Table 5's convnext_base row).
fn convnext_like(batch: usize) -> dippm::ir::Graph {
    use dippm::ir::{Attrs, GraphBuilder, OpKind};
    let mut b = GraphBuilder::new("convnext", &format!("convnext-like-b{batch}"), batch);
    let x = b.input(vec![batch, 3, 224, 224]);
    let mut h = b.conv2d(x, 96, 4, 4, 0); // patchify stem
    let mut dim = 96;
    for (stage, blocks) in [(0, 2), (1, 2), (2, 4), (3, 2)] {
        for _ in 0..blocks {
            // ConvNeXt block: dw 7x7 -> norm -> pw expand -> gelu -> pw
            let dw = b.depthwise(h, 7, 1, 3);
            let n = b.add(OpKind::BatchNorm, Attrs::none(), &[dw]);
            let e = b.conv2d(n, dim * 4, 1, 1, 0);
            let g = b.add(OpKind::Gelu, Attrs::none(), &[e]);
            let p = b.conv2d(g, dim, 1, 1, 0);
            h = b.add(OpKind::Add, Attrs::none(), &[p, h]);
        }
        if stage < 3 {
            dim *= 2;
            h = b.conv2d(h, dim, 2, 2, 0); // downsample
        }
    }
    let p = b.add(OpKind::GlobalAvgPool2d, Attrs::none(), &[h]);
    let f = b.add(OpKind::Flatten, Attrs::none(), &[p]);
    b.dense(f, 1000);
    b.finish()
}
