//! Serving demo: start the coordinator + TCP front end, then hammer it from
//! multiple client threads sending models in four different framework
//! formats — showing cross-connection dynamic batching, the JSON-lines
//! protocol and the graph-fingerprint prediction cache (clients re-send the
//! same small model set, so most requests answer from the LRU without
//! touching the runtime). Prints throughput, batching and cache metrics.
//!
//! Uses the PJRT backend when AOT artifacts are built, else the hermetic
//! simulator backend — the serving stack is identical.
//!
//! Run: `cargo run --release --example serve_demo`

use std::sync::Arc;

use dippm::coordinator::{tcp, Coordinator, CoordinatorOptions};
use dippm::frontends::{self, Framework};
use dippm::modelgen::Family;
use dippm::runtime::Runtime;
use dippm::util::json::Json;

fn start_coordinator() -> anyhow::Result<Arc<Coordinator>> {
    // Untrained params keep the demo fast; swap in ParamStore::load(...) for
    // real predictions (see quickstart / e2e_reproduce). Any PJRT-side
    // failure (missing artifacts, bad checkpoint, runtime startup) falls
    // back to the simulator backend — the serving stack is identical.
    let pjrt = (|| -> anyhow::Result<Coordinator> {
        let rt = Runtime::new("artifacts")?;
        let params = rt.init_params("sage", 0)?;
        drop(rt);
        Coordinator::start("artifacts", params, CoordinatorOptions::default())
    })();
    match pjrt {
        Ok(coord) => {
            println!("backend: pjrt (artifacts found)");
            Ok(Arc::new(coord))
        }
        Err(e) => {
            println!("backend: simulator ({e:#})");
            Ok(Arc::new(Coordinator::start_sim(
                CoordinatorOptions::default(),
            )?))
        }
    }
}

fn main() -> anyhow::Result<()> {
    let coord = start_coordinator()?;

    let (port_tx, port_rx) = std::sync::mpsc::channel();
    {
        let coord = coord.clone();
        std::thread::spawn(move || {
            tcp::serve(coord, "127.0.0.1:0", move |p| {
                let _ = port_tx.send(p);
            })
            .unwrap();
        });
    }
    let port = port_rx.recv()?;
    println!("serving on 127.0.0.1:{port}\n");

    let t0 = std::time::Instant::now();
    let per_client = 12;
    let clients: Vec<_> = [
        (Framework::PyTorch, Family::ResNet),
        (Framework::TensorFlow, Family::Vgg),
        (Framework::Paddle, Family::MobileNet),
        (Framework::Native, Family::Vit),
    ]
    .into_iter()
    .map(|(fw, family)| {
        std::thread::spawn(move || {
            let mut client = tcp::Client::connect(&format!("127.0.0.1:{port}")).unwrap();
            let mut ok = 0;
            for i in 0..per_client {
                // Cycle a small variant set: repeats hit the fingerprint
                // cache no matter which framework format carried them.
                let g = family.generate(i % 3);
                let model = frontends::export(fw, &g);
                let compact = Json::parse(&model).unwrap().to_string();
                let line =
                    format!("{{\"framework\":\"{}\",\"model\":{compact}}}", fw.name());
                let resp = client.roundtrip(&line).unwrap();
                let v = Json::parse(&resp).unwrap();
                assert_eq!(v.path(&["ok"]).as_bool(), Some(true), "{resp}");
                if i == 0 {
                    println!(
                        "[{}/{}] {} -> latency {:.2} ms, MIG {}",
                        fw.name(),
                        family.name(),
                        g.variant,
                        v.path(&["latency_ms"]).as_f64().unwrap_or(-1.0),
                        v.path(&["mig_profile"])
                            .as_str()
                            .unwrap_or("None")
                    );
                }
                ok += 1;
            }
            ok
        })
    })
    .collect();

    let total: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
    let el = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "\n{total} requests over 4 framework formats in {el:.2}s = {:.1} req/s",
        total as f64 / el
    );
    println!(
        "batches: {}, mean fill: {:.2}, errors: {}",
        m.batches,
        m.mean_batch_fill(),
        m.errors
    );
    println!(
        "cache: {} hits / {} misses ({:.0}% hit rate), {} coalesced, {} entries",
        m.cache_hits,
        m.cache_misses,
        100.0 * m.cache_hit_rate(),
        m.coalesced,
        m.cache_entries
    );

    // The cache_stats admin command reports the same counters over TCP.
    let mut client = tcp::Client::connect(&format!("127.0.0.1:{port}"))?;
    println!("cache_stats -> {}", client.cache_stats()?);
    Ok(())
}
