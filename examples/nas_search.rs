//! Predictor-guided NAS through the sweep verb — the paper's intro
//! motivates DIPPM for "efficient Neural Architecture Search": candidate
//! architectures are scored by the *trained predictor* instead of being
//! run on the device. Instead of one request per candidate, the search
//! ships each family's base architecture once and lets the server expand
//! the depth × width × batch grid, dedup it against the prediction cache,
//! and stream back scored candidates. The device simulator then verifies
//! the final picks — measuring how much the predictor's ranking agrees
//! with ground truth.
//!
//! Run: `cargo run --release --example nas_search`
//!
//! Pass `--client-loop` for the old per-candidate random search (one
//! predict round trip per candidate — the bench baseline).

use std::sync::{mpsc, Arc};

use dippm::coordinator::{expand, Coordinator, CoordinatorOptions, SweepSpec};
use dippm::dataset::Dataset;
use dippm::ir::Graph;
use dippm::modelgen::ALL_FAMILIES;
use dippm::runtime::Runtime;
use dippm::simulator::Simulator;
use dippm::training::{TrainConfig, Trainer};
use dippm::util::bench::Table;
use dippm::util::rng::Rng;
use dippm::wire::{reactor, ReactorConfig, WireClient};

const LATENCY_BUDGET_MS: f64 = 5.0;
const MEMORY_BUDGET_MB: f64 = 5.0 * 1024.0; // must fit a 1g.5gb MIG slice

/// Start the binary reactor on an ephemeral port; returns its address.
fn serve(coord: Arc<Coordinator>) -> String {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        reactor::serve(coord, "127.0.0.1:0", ReactorConfig::default(), move |p| {
            let _ = tx.send(p);
        })
        .unwrap();
    });
    format!("127.0.0.1:{}", rx.recv().unwrap())
}

fn main() -> anyhow::Result<()> {
    let client_loop = std::env::args().any(|a| a == "--client-loop");

    // Train the predictor briefly (reuse a checkpoint in real use).
    println!("[setup] training the predictor...");
    let ds = Dataset::build(0.06, 42, 0);
    let rt = Runtime::new("artifacts")?;
    let mut trainer = Trainer::new(
        &rt,
        TrainConfig {
            epochs: 12,
            lr: 3e-3,
            ..Default::default()
        },
    )?;
    for e in 0..trainer.config.epochs {
        trainer.train_epoch(&ds, e)?;
    }
    let mape = trainer.evaluate(&ds, &ds.splits.test)?.overall();
    println!("[setup] predictor test MAPE {mape:.3}");
    let params = trainer.params.clone();
    drop(trainer);
    drop(rt);
    let coord = Arc::new(Coordinator::start(
        "artifacts",
        params,
        CoordinatorOptions::default(),
    )?);
    let addr = serve(coord);
    let mut client = WireClient::connect(&addr)?;

    println!("\n[search] budget: latency < {LATENCY_BUDGET_MS} ms, memory < {MEMORY_BUDGET_MB:.0} MB (1g.5gb)\n");
    let mut feasible: Vec<(Graph, f64, f64)> = Vec::new();
    let mut scored = 0usize;
    let t0 = std::time::Instant::now();

    if client_loop {
        // Baseline: random search, one predict round trip per candidate.
        let mut rng = Rng::new(2026);
        let n_candidates = 120;
        println!("[search] client loop: scoring {n_candidates} random candidates one by one");
        for _ in 0..n_candidates {
            let family = *rng.choose(&ALL_FAMILIES);
            let idx = rng.below(family.grid_size());
            let g = family.generate(idx);
            let pred = client.predict_graph(&g)?;
            scored += 1;
            if pred.latency_ms < LATENCY_BUDGET_MS && pred.memory_mb < MEMORY_BUDGET_MB {
                feasible.push((g, pred.latency_ms, pred.memory_mb));
            }
        }
    } else {
        // One sweep per family: the server expands and scores the grid,
        // the client only filters the streamed results. The same
        // expansion runs locally (it is deterministic) so the simulator
        // can verify picks without a graph ever crossing the wire twice.
        let spec = SweepSpec {
            depths: vec![1, 2],
            widths: vec![100, 75, 50],
            batches: vec![1, 4],
            ..SweepSpec::default()
        };
        println!(
            "[search] server sweep: {} candidates per family, one round trip each family",
            spec.total()
        );
        for family in ALL_FAMILIES {
            let base = family.generate(0);
            let local = expand(&base, &spec);
            let (items, summary) = client.sweep(&base, None, &spec)?;
            scored += summary.candidates as usize;
            for it in &items {
                let Ok(pred) = &it.result else { continue };
                if pred.latency_ms < LATENCY_BUDGET_MS && pred.memory_mb < MEMORY_BUDGET_MB {
                    if let Some(Ok(g)) = local.get(it.index as usize).map(|c| &c.graph) {
                        feasible.push((g.clone(), pred.latency_ms, pred.memory_mb));
                    }
                }
            }
        }
    }
    let search_s = t0.elapsed().as_secs_f64();
    println!(
        "[search] {} feasible / {scored} scored in {search_s:.1}s ({:.0} cand/s — no GPU runs)",
        feasible.len(),
        scored as f64 / search_s
    );

    // Rank by predicted latency, verify the top picks on the device model.
    feasible.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let sim = Simulator::new();
    let mut t = Table::new(&[
        "candidate", "batch", "pred lat (ms)", "true lat (ms)", "pred mem",
        "true mem", "budget ok?",
    ]);
    let mut verified = 0;
    let top: Vec<_> = feasible.iter().take(8).collect();
    for (g, pl, pm) in &top {
        let m = sim.measure(g);
        let ok = m.latency_ms < LATENCY_BUDGET_MS && m.memory_mb < MEMORY_BUDGET_MB;
        verified += ok as usize;
        t.row(&[
            g.variant.clone(),
            g.batch.to_string(),
            format!("{pl:.3}"),
            format!("{:.3}", m.latency_ms),
            format!("{pm:.0}"),
            format!("{:.0}", m.memory_mb),
            if ok { "Y".into() } else { "n".into() },
        ]);
    }
    t.print();
    println!(
        "\n{verified}/{} of the predictor's top picks verified within budget on the device model.",
        top.len()
    );

    // Ranking agreement: Spearman-ish check on the feasible set.
    let sample: Vec<_> = feasible.iter().take(20).collect();
    let mut concordant = 0;
    let mut total_pairs = 0;
    for i in 0..sample.len() {
        for j in i + 1..sample.len() {
            let ti = sim.measure(&sample[i].0).latency_ms;
            let tj = sim.measure(&sample[j].0).latency_ms;
            total_pairs += 1;
            if (sample[i].1 < sample[j].1) == (ti < tj) {
                concordant += 1;
            }
        }
    }
    if total_pairs > 0 {
        println!(
            "pairwise ranking agreement (pred vs device): {:.0}% over {total_pairs} pairs",
            100.0 * concordant as f64 / total_pairs as f64
        );
    }
    Ok(())
}
